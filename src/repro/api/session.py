"""``Session`` — executes a ``RunSpec``; the runtime half of the API.

The trainer and the server used to each hand-assemble the same lifecycle:
build mesh/engine -> attach ControlPlane -> attach Autoscaler -> connect a
JobManagerClient -> tear everything down in the right order.  ``Session``
owns that lifecycle once:

    spec = RunSpec.load("configs/scenarios/early_exit.json")
    with Session(spec) as s:
        report = s.train()          # or s.serve()
    for ev in s.events:             # structured telemetry stream
        print(ev.kind, ev.step, ev.data)

``train``/``serve`` return the same report dicts the legacy entry points
did (every existing test/bench reads them); ``session.events`` is the
structured stream — one ``SessionEvent`` per resize / rebalance /
autoscale decision / log line — that new tooling should consume instead.

Teardown order matters and is centralized in ``close()``: control plane
first (its worker thread must stop deciding against a dying engine), then
the engine/server (detach pool hooks), then the job-manager client (tells
a file-RPC server process to exit), then the server process wait.
"""
from __future__ import annotations

import os

# honor the forced-host-device knob at the front door too (the launch CLIs
# set it in their own preambles; a program importing repro.api directly —
# examples, notebooks — must get it before the lazy jax import below)
if (os.environ.get("REPRO_TRAIN_DEVICES")
        and "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_TRAIN_DEVICES"])

import dataclasses
import tempfile
import time
import warnings
from typing import Any, Dict, List, Optional

from repro.api.specs import RunSpec
from repro.obs.events import EVENT_SCHEMA, stamp_record
from repro.obs.metrics import MetricsRegistry


@dataclasses.dataclass
class SessionEvent:
    """One telemetry record: ``kind`` in {"log", "rebalance", "resize",
    "autoscale", "safepoint", "relayout", "serve_summary",
    "train_summary", "tenant_register", "preempt", "absorb", "steal",
    "yield"} — the last five are the multi-tenant cluster stream
    (DESIGN.md §14).

    Since schema v4 every record also carries the unified event fields
    (DESIGN.md §15): ``schema``/``source``/``wall`` plus tracing identity
    when the session has a tracer.  The legacy ``kind``/``step``/``data``
    triple is unchanged — old consumers keep working."""
    kind: str
    step: int
    data: Dict[str, Any]
    schema: str = EVENT_SCHEMA
    source: str = "session"
    wall: Optional[float] = None
    trace_id: Optional[str] = None
    span_id: Optional[str] = None
    parent_id: Optional[str] = None
    lc: Optional[int] = None
    cause_trace_id: Optional[str] = None


class Session:
    """Context manager that executes one ``RunSpec``."""

    def __init__(self, spec: RunSpec):
        self.spec = spec
        self.events: List[SessionEvent] = []
        self._cp = None          # cluster.service.ControlPlane
        self._engine = None      # launch.engine.ElasticEngine
        self._server = None      # serve.server.ElasticServer
        self._jm = None          # cluster.rpc.JobManagerClient
        self._jm_proc = None
        self._jm_dir = None
        self._closed = False
        self.injector = None     # faults.ChaosInjector when chaos is on
        self._resume_dir: Optional[str] = None
        self._resume_step: Optional[int] = None
        # ---- observability (DESIGN.md §15) --------------------------------
        self.metrics = MetricsRegistry()   # always live; ~free when unread
        self.tracer = None                 # obs.trace.Tracer when obs.trace
        self._metrics_srv = None           # http server when obs.metrics_port

    @classmethod
    def resume(cls, ckpt_dir: str, *,
               step: Optional[int] = None) -> "Session":
        """Rebuild a crashed run from its newest complete safe point.  The
        safe point carries the producing ``RunSpec``, so the caller needs
        nothing but the directory; ``train()`` then restores tensors,
        stage→worker topology, pool state, and control-plane hysteresis and
        continues from the step after the safe point — bit-identically to
        the run that never crashed (DESIGN.md §12)."""
        from repro.checkpoint.safepoint import peek
        idx = peek(ckpt_dir, step)
        spec = RunSpec.from_dict(idx["meta"]["spec"])
        s = cls(spec)
        s._resume_dir = ckpt_dir
        s._resume_step = int(idx["step"])
        return s

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._obs_end()
        if self._cp is not None:
            self._cp.close()
        if self._server is not None:
            self._server.close()
        elif self._engine is not None:
            # deliver bookkeeping deferred while the manager was down —
            # best-effort; an unreachable manager must not block teardown
            self._engine._flush_pending_jm()
            self._engine.close()
        if self._jm is not None:
            self._jm.close()             # tells a file-RPC server to exit
        if self._jm_proc is not None:
            try:
                self._jm_proc.wait(timeout=10)
            except Exception:
                self._jm_proc.kill()

    def _emit(self, kind: str, step: int, *, cause_ctx=None,
              **data) -> SessionEvent:
        rec: Dict[str, Any] = {}
        stamp_record(rec, source="session", kind=kind, tracer=self.tracer,
                     ctx=cause_ctx)
        ev = SessionEvent(kind, step, data, wall=rec.get("wall"),
                          trace_id=rec.get("trace_id"),
                          span_id=rec.get("span_id"),
                          parent_id=rec.get("parent_id"), lc=rec.get("lc"),
                          cause_trace_id=rec.get("cause_trace_id"))
        self.events.append(ev)
        return ev

    # -- observability lifecycle (DESIGN.md §15) ---------------------------
    def _obs_begin(self, mode: str):
        """Build the tracer / metrics endpoint per ``spec.obs``.  The
        trace id derives from run identity (mode + tenant + seed), never
        pids or clocks, so a fixed-seed run's logical event sequence is
        reproducible (tested)."""
        obs = self.spec.obs
        if obs.trace:
            from repro.obs.trace import Tracer, set_current_tracer
            if self.tracer is None:
                tenant = self.spec.cluster.tenant_id or "solo"
                self.tracer = Tracer(
                    f"{mode}-{tenant}-s{self.spec.seed}",
                    meta={"mode": mode, "tenant": tenant,
                          "seed": self.spec.seed})
            # deep layers (RPC clients, control plane, injector) find the
            # tracer here instead of via constructor threading
            set_current_tracer(self.tracer)
        if obs.metrics_port and self._metrics_srv is None:
            from repro.obs.metrics import serve_metrics
            self._metrics_srv = serve_metrics(self.metrics,
                                              obs.metrics_port)
        return self.tracer

    def _obs_end(self) -> None:
        obs = self.spec.obs
        if self._metrics_srv is not None:
            self._metrics_srv.shutdown()
            self._metrics_srv = None
        if self.tracer is not None:
            if obs.trace_out:
                self.tracer.export(obs.trace_out)
            from repro.obs.trace import current_tracer, set_current_tracer
            if current_tracer() is self.tracer:
                set_current_tracer(None)
        if obs.metrics_out:
            self.metrics.save(obs.metrics_out)

    # -- shared assembly ---------------------------------------------------
    def _model_config(self):
        from repro.configs.base import get_config, reduced_config
        m = self.spec.model
        cfg = get_config(m.arch)
        if m.layers is not None:
            cfg = reduced_config(cfg, num_layers=m.layers, d_model=m.d_model,
                                 num_heads=m.num_heads,
                                 num_kv_heads=m.num_kv_heads,
                                 d_ff=m.d_ff or 2 * m.d_model,
                                 vocab_size=m.vocab_size)
        return cfg

    def _dist_config(self):
        from repro.configs.base import DistConfig
        p = self.spec.parallel
        return DistConfig(num_stages=p.stages, slot_slack=p.slot_slack,
                          remat=p.remat, param_dtype=p.param_dtype,
                          kernel_impl=p.kernel_impl)

    def _connect_job_manager(self, plan=None, injector=None,
                             pool_state=None):
        """'file' spawns the WorkerPool server in a separate process and
        returns a client speaking atomic req/resp JSON files to it; 'http'
        connects to ``cluster.manager_url`` when set (two Sessions in two
        processes contending over ONE manager — DESIGN.md §14) or spawns a
        private HTTP manager; 'inproc' returns None (the engine wraps its
        own pool).  ``pool_state`` (from a safe point) is seeded into the
        fresh directory as the server's journal, so the respawned server
        starts from the crashed run's pool topology; with an RPC-chaos
        ``plan`` the client is the chaos transport."""
        import json

        from repro.cluster.rpc import FileJobManager, spawn_file_manager
        c = self.spec.cluster
        if c.job_manager == "inproc":
            return None
        if c.job_manager == "http":
            from repro.cluster.http_rpc import (HttpJobManager,
                                                spawn_http_manager)
            if c.manager_url:
                # shared manager owned by someone else: never shut it down
                self._jm = HttpJobManager(c.manager_url,
                                          timeout_s=c.rpc_timeout_s,
                                          shutdown_on_close=False)
                return self._jm
            if c.job_manager_dir:
                os.makedirs(c.job_manager_dir, exist_ok=True)
                run_dir = tempfile.mkdtemp(prefix="run_",
                                           dir=c.job_manager_dir)
            else:
                run_dir = tempfile.mkdtemp(prefix="dynmo_jm_")
            if pool_state is not None:
                with open(os.path.join(run_dir, "state.json"), "w") as f:
                    json.dump({"pool": pool_state, "answered": {}}, f)
            self._jm_dir = run_dir
            self._jm_proc, url = spawn_http_manager(
                run_dir, self.spec.parallel.stages, spares=c.spares)
            self._jm = HttpJobManager(url, timeout_s=c.rpc_timeout_s,
                                      shutdown_on_close=True)
            return self._jm
        # always a FRESH directory (a unique subdir when the caller names a
        # location): leftover req/resp files from a previous run would be
        # replayed by the new server and misread by the new client
        if c.job_manager_dir:
            os.makedirs(c.job_manager_dir, exist_ok=True)
            jm_dir = tempfile.mkdtemp(prefix="run_", dir=c.job_manager_dir)
        else:
            jm_dir = tempfile.mkdtemp(prefix="dynmo_jm_")
        if pool_state is not None:
            with open(os.path.join(jm_dir, "state.json"), "w") as f:
                json.dump({"pool": pool_state, "answered": {}}, f)
        self._jm_dir = jm_dir
        self._jm_proc = spawn_file_manager(jm_dir, self.spec.parallel.stages,
                                           spares=c.spares)
        if plan is not None and plan.any_rpc:
            from repro.faults import ChaosFileJobManager
            self._jm = ChaosFileJobManager(jm_dir, plan, injector,
                                           timeout_s=c.rpc_timeout_s)
        else:
            self._jm = FileJobManager(jm_dir, timeout_s=c.rpc_timeout_s)
        return self._jm

    def _register_tenant(self, jm, *, kind: str, workers: int,
                         max_workers: int, min_workers: int):
        """Register this Session with the cluster scheduler when the spec
        names a tenant.  Returns the granted worker ids (to bind the engine
        onto) or None when running single-tenant."""
        c = self.spec.cluster
        if jm is None or not c.tenant_id \
                or not hasattr(jm, "register_tenant"):
            return None
        granted = jm.register_tenant(
            c.tenant_id, priority=c.priority, kind=kind, workers=workers,
            max_workers=max_workers, min_workers=min_workers)
        if not granted:
            raise RuntimeError(
                f"cluster scheduler granted no workers to tenant "
                f"{c.tenant_id!r} (pool exhausted?)")
        self._emit("tenant_register", -1, tenant=c.tenant_id,
                   priority=c.priority, tenant_kind=kind,
                   granted=list(granted))
        return granted

    # =======================================================================
    # Training
    # =======================================================================
    def train(self, steps: Optional[int] = None, *,
              shrink_at: Optional[Dict[int, int]] = None) -> Dict[str, Any]:
        """Run the DynMo training loop for ``steps`` (default: spec.steps).
        ``shrink_at`` scripts {step: target_stages} voluntary safe-point
        shrinks (tests/demos) through the same epoch-fenced injection an
        external preemption directive uses — the bit-identity oracle for
        the multi-tenant steal path (DESIGN.md §14).
        Returns the report dict (losses, events, resizes, telemetry)."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
        from repro.cluster.service import ControlPlane, StatsSnapshot
        from repro.core.controller import ControllerConfig, DynMoController
        from repro.data.loader import DataConfig, make_loader
        from repro.dynamics import pruning as prn
        from repro.dynamics.trajectories import zhu_gupta_sparsity
        from repro.launch.engine import ElasticEngine
        from repro.optim.schedule import cosine_schedule
        from repro.pipeline.pipeline import PipelineShapes
        from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                                   StragglerDetector)

        spec = self.spec
        obs = spec.obs
        tracer = self._obs_begin("train")
        mreg = self.metrics
        steps = steps if steps is not None else spec.steps
        stages = spec.parallel.stages
        seq = spec.parallel.seq
        dynamism = spec.dynamics.kind
        straggler = spec.controller.straggler
        measure_stage_times = spec.controller.measure_stage_times
        repack_target = spec.controller.repack.target
        grow_back = spec.cluster.grow_back
        if grow_back is not None:
            warnings.warn(
                "cluster.grow_back / --grow-back is deprecated: fixed-step "
                "re-expansion is superseded by signal-driven scaling "
                "(cluster.autoscale / --autoscale)", DeprecationWarning,
                stacklevel=2)

        cfg = self._model_config()
        dcfg = self._dist_config()
        dyncfg = spec.dynamics.to_config()
        shapes = PipelineShapes(num_micro=spec.parallel.num_micro,
                                mb_global=spec.parallel.mb_global, seq=seq)
        tokens_per_step = (spec.parallel.num_micro
                           * spec.parallel.mb_global * seq)

        # ---- resume point (safe-point metadata drives everything below)
        resume_idx = None
        start_step = 0
        if self._resume_dir:
            from repro.checkpoint.safepoint import peek
            resume_idx = peek(self._resume_dir, self._resume_step)
            start_step = int(resume_idx["step"]) + 1
        rmeta = resume_idx["meta"] if resume_idx is not None else {}

        # ---- chaos: resolve the fault plan before anything it may target
        # (named fplan — the controller's DecisionPlan reuses ``plan``
        # inside the step loop)
        fplan = injector = None
        if spec.faults.enabled:
            from repro.faults import ChaosInjector, resolve_plan
            if spec.faults.worker_crash and not spec.cluster.autoscale:
                raise ValueError(
                    "faults.worker_crash requires cluster.autoscale: the "
                    "heartbeat -> autoscaler -> evict pipeline IS the "
                    "recovery path chaos exercises")
            fplan = resolve_plan(
                spec.faults, horizon=steps,
                workers=(stages if spec.cluster.autoscale else 1),
                file_manager=spec.cluster.job_manager == "file")
            injector = ChaosInjector(fplan, start_step=start_step,
                                     resumed=resume_idx is not None)
            self.injector = injector

        jm = self._connect_job_manager(
            plan=fplan, injector=injector,
            pool_state=(rmeta.get("pool")
                        if spec.cluster.job_manager == "file" else None))
        pool = None
        if jm is None:
            from repro.runtime.fault_tolerance import WorkerPool
            if resume_idx is not None and rmeta.get("pool"):
                pool = WorkerPool.from_state(rmeta["pool"])
            elif spec.cluster.spares:
                pool = WorkerPool(stages, spares=spec.cluster.spares)
        engine = ElasticEngine(cfg, dcfg, dyncfg, shapes,
                               data=spec.parallel.data, pool=pool,
                               job_manager=jm,
                               in_step_timing=obs.in_step_timing)
        self._engine = engine
        if injector is not None:
            import signal

            def _kill_manager():
                if self._jm_proc is not None:
                    self._jm_proc.kill()
                    self._jm_proc.wait()

            def _respawn_manager():
                from repro.cluster.rpc import spawn_file_manager
                self._jm_proc = spawn_file_manager(self._jm_dir, stages,
                                                   spares=spec.cluster
                                                   .spares)

            cbs = {"kill_self":
                   lambda: os.kill(os.getpid(), signal.SIGKILL)}
            if spec.cluster.job_manager == "file":
                cbs["kill_manager"] = _kill_manager
                cbs["respawn_manager"] = _respawn_manager
            injector.bind(**cbs)
        if resume_idx is not None:
            # rebuild at the stage count the run died at, then overwrite
            # the randomly-initialized tensors with the safe point's shards
            # (bit-exact) and re-place them on the restored world's submesh
            from repro.checkpoint.safepoint import restore
            engine.bind_workers([int(w) for w in rmeta["stage_workers"]])
            state = engine.init_state(
                jax.random.PRNGKey(spec.seed),
                stages=int(resume_idx["num_stages"]),
                lps=[int(x) for x in resume_idx["layers_per_stage"]])
            p, o, d, _ = restore(
                self._resume_dir,
                (state.params, state.opt_state, state.dyn),
                int(resume_idx["step"]))
            w = engine.world(state.stages)
            (state.params, state.opt_state, state.dyn, state.assignment,
             _) = engine._place(w, p, o, d, state.assignment)
            engine.epoch = int(rmeta.get("epoch", 0))
        else:
            tenant_min = max(1, repack_target)
            granted = self._register_tenant(
                jm, kind="train", workers=stages, max_workers=stages,
                min_workers=tenant_min)
            if granted is not None:
                # train on exactly the granted workers (arbitrary global
                # ids — another tenant may hold 0..k): same bind +
                # sized-init path the checkpoint resume uses
                engine.bind_workers([int(w) for w in granted])
                state = engine.init_state(jax.random.PRNGKey(spec.seed),
                                          stages=len(granted))
            else:
                state = engine.init_state(jax.random.PRNGKey(spec.seed))

        ccfg = ControllerConfig(method=spec.controller.balancer,
                                rebalance_every=spec.controller
                                .rebalance_every,
                                repack=spec.controller.repack.enabled,
                                repack_policy=spec.controller.repack.policy,
                                repack_target=max(1, repack_target),
                                expert_relayout=dyncfg.expert_relayout,
                                expert_watermark=dyncfg.expert_watermark,
                                expert_min_tokens=dyncfg.expert_min_tokens)
        if spec.controller.repack.enabled:
            # per-worker memory budget: capacity factor × the dtype-correct
            # per-stage footprint of the UNPRUNED model under a uniform
            # split — consolidation becomes feasible once dynamism shrinks
            # the model
            from repro.core.cost_model import stage_memory_budget
            ccfg.repack_mem_cap = stage_memory_budget(
                cfg, tokens_per_step, seq, dcfg.bytes_per_param, stages,
                cap_factor=spec.controller.repack.mem_cap)
        if resume_idx is not None and rmeta.get("repack_enabled") is False:
            # the crashed run had already latched repack off (a grow keeps
            # granted workers); the resumed one must not re-plan a shrink
            ccfg.repack = False
        det = StragglerDetector(stages) \
            if (straggler or measure_stage_times) else None
        ctrl = DynMoController(cfg, dcfg, dyncfg, ccfg, straggler=det)
        cp = ControlPlane(ctrl, async_mode=spec.controller.async_decide,
                          epoch_fn=lambda: engine.epoch)
        self._cp = cp
        if resume_idx is not None:
            cp.rebind(engine.dcfg_for(state.stages), state.lps)

        # ---- autoscaler: heartbeats + throughput watermark; the monitor
        # runs on a step-granular simulated clock so CI is deterministic
        monitor = scaler = None
        sim_clock = [0.0]
        if spec.cluster.autoscale:
            monitor = HeartbeatMonitor(
                stages, timeout_s=spec.cluster.heartbeat_timeout,
                clock=lambda: sim_clock[0])
            scaler = Autoscaler(
                AutoscalerConfig(min_stages=max(1, repack_target),
                                 max_stages=stages,
                                 watermark=spec.cluster.autoscale_watermark),
                monitor)
            if resume_idx is not None and rmeta.get("scaler"):
                scaler.load_state(rmeta["scaler"])

        loader = make_loader(cfg, DataConfig(spec.parallel.num_micro,
                                             spec.parallel.mb_global, seq,
                                             seed=spec.seed),
                             start_step=start_step)
        ckpt = safept = None
        if spec.ckpt_every:
            from repro.checkpoint.safepoint import SafepointManager
            safept = SafepointManager(spec.ckpt_dir, every=spec.ckpt_every)
        elif spec.ckpt_dir:
            from repro.checkpoint.checkpoint import CheckpointManager
            ckpt = CheckpointManager(spec.ckpt_dir,
                                     every=max(10, steps // 5))

        def after_resize(step: int, kind: str) -> None:
            cp.rebind(engine.dcfg_for(state.stages), state.lps)
            if scaler is not None:
                scaler.note_resize(step, state.stages)
            rz = engine.resizes[-1]
            if monitor is not None and rz.kind == "shrink":
                # released workers leave the heartbeat set deliberately; a
                # later revive is the recovery signal the autoscaler grows
                # on
                for w in rz.workers:
                    monitor.expire(w)
            if monitor is not None and rz.kind == "grow":
                # regranted workers (any grow path) must beat again —
                # without the revive they would stay marked failed and a
                # later real death of the same worker could never be
                # detected
                for w in rz.workers:
                    monitor.revive(w)
            self._emit("resize", step, resize_kind=kind,
                       from_stages=rz.from_stages, to_stages=rz.to_stages,
                       workers=list(rz.workers),
                       ticks_before=rz.ticks_before,
                       ticks_after=rz.ticks_after)
            print(f"step {step:4d} {kind.upper()} {rz.from_stages}->"
                  f"{rz.to_stages} stages; workers {rz.workers}; "
                  f"pool active={engine.jm.num_active}; schedule "
                  f"{rz.ticks_before}->{rz.ticks_after} ticks")

        # multi-tenant: poll the cluster scheduler's directive mailbox each
        # step (preempt = shrink at this safe point; offer = absorb free
        # workers back off-peak, DESIGN.md §14)
        multi_tenant = (jm is not None and spec.cluster.tenant_id
                        and getattr(jm, "tenant", None))
        tenant_min = max(1, repack_target)
        last_cluster_resize = start_step - 1
        absorb_cooldown = max(1, spec.controller.rebalance_every)

        losses, events, step_times, stages_hist = [], [], [], []
        relayouts: List[Dict[str, Any]] = []
        expert_skew_last = moe_dropped_last = None
        last_measured = None
        # ---- step-time accounting (DESIGN.md §15): warm-up steps (the
        # first step on each freshly-built world pays the jit compile) and
        # controller-cadence decide time are tracked SEPARATELY from the
        # steady-state step times, so tok/s and per-step histograms are
        # not skewed by one 30 s compile
        stage_time_source = None
        preempt_ctx = None
        warmup_steps, warmup_s, decide_s = 0, 0.0, 0.0
        steady_times: List[float] = []
        root_span = (tracer.span("train", cat="session", steps=steps,
                                 stages=stages) if tracer is not None
                     else None)
        t0 = time.perf_counter()
        for step, batch in enumerate(loader, start=start_step):
            if step >= steps:
                break
            t_step = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            lr = cosine_schedule(jnp.float32(step), steps, 3e-4, warmup=10)
            sp_step = (tracer.span("train.step", cat="train", step=step,
                                   stages=state.stages)
                       if tracer is not None else None)
            loss, stats, gnorm = engine.step(state, batch, lr)
            # one scalar sync for the loss curve; the full per-slot stats
            # tree stays on device until controller cadence (§3.3.1)
            losses.append(float(loss))
            if sp_step is not None:
                sp_step.end(compiled=engine.last_step_compiled)
            dt = time.perf_counter() - t_step
            step_times.append(dt)
            stages_hist.append(state.stages)
            if engine.last_step_compiled:
                warmup_steps += 1
                warmup_s += dt
            else:
                steady_times.append(dt)
                mreg.observe("dynmo_step_seconds", dt,
                             help="steady-state train step wall seconds")
            mreg.inc("dynmo_train_steps_total",
                     help="train steps executed")
            mreg.set("dynmo_stages", state.stages,
                     help="current pipeline stage count")

            # ---- dynamism events (black-box to the controller)
            if dynamism == "pruning" and step and step % 10 == 0:
                sp = zhu_gupta_sparsity(
                    step * 100, dataclasses.replace(
                        dyncfg, prune_start_iter=0,
                        prune_end_iter=steps * 100, prune_frequency=1))
                keep = prn.target_keep_blocks(
                    cfg, cfg.total_blocks(), sp)
                dyn = dict(state.dyn)
                dyn["ff_mask"] = prn.global_block_prune(
                    cfg, state.params["stages"], state.assignment["tags"],
                    keep)
                state.dyn = dyn
            if dynamism == "freezing" and step and step % 10 == 0:
                front = int(cfg.total_blocks() * min(0.6, step / steps))
                fr = np.zeros_like(np.asarray(state.dyn["frozen"]))
                g = 0
                tags_np = np.asarray(state.assignment["tags"])
                for s in range(tags_np.shape[0]):
                    for l in range(tags_np.shape[1]):
                        if tags_np[s, l] != 0:
                            if g < front:
                                fr[s, l] = 1.0
                            g += 1
                dyn = dict(state.dyn)
                dyn["frozen"] = jnp.asarray(fr)
                state.dyn = dyn

            # ---- heartbeats (simulated per-step liveness: active workers
            # beat; released/dead ones go silent and time out)
            if monitor is not None:
                sim_clock[0] = float(step)
                beat = engine.stage_workers if injector is None \
                    else injector.heartbeat_workers(engine.stage_workers)
                for w in beat:
                    monitor.beat(w)
                if (spec.cluster.simulate_recover is not None
                        and step == spec.cluster.simulate_recover):
                    for w in range(stages):
                        if w not in engine.stage_workers:
                            monitor.revive(w)

            # ---- publish stats to the control plane on cadence (the only
            # device→host stats sync; in async mode this is a pointer swap)
            if ctrl.cadence(step + 1):
                t_decide = time.perf_counter()
                sp_dec = (tracer.span("controller.decide", cat="controller",
                                      step=step)
                          if tracer is not None else None)
                measured = None
                src = None
                if obs.in_step_timing:
                    # live per-stage seconds folded from the in-step
                    # stage-boundary stamps (DESIGN.md §15) — costs no
                    # extra execution; the probe below stays available
                    # behind controller.measure_stage_times as the
                    # parity oracle
                    measured = engine.in_step_stage_times(state)
                    if measured is not None:
                        src = "in_step"
                if measured is None and measure_stage_times:
                    # real per-stage wall times from the engine's stage
                    # probe — cadence-gated here so the hot path stays
                    # sync-free (the probe is a per-stage host sync)
                    measured = engine.measure_stage_times(state, batch)
                    if measured is not None:
                        src = "probe"
                if measured is not None:
                    last_measured = measured
                    stage_time_source = src
                    for s in range(len(measured)):
                        mreg.set("dynmo_stage_time_seconds",
                                 float(measured[s]),
                                 help="per-stage busy seconds per step",
                                 stage=s, source=src)
                if straggler:
                    # simulation knob: a straggling WORKER multiplies its
                    # stage's wall time; feed the detector the same shape a
                    # real per-worker timer would report (or skew the
                    # measured times when both are on).  Keyed by WORKER
                    # id — after an evict/resize the slow machine keeps its
                    # id but sits at a different stage index
                    if measured is None:
                        share = np.asarray(state.lps, np.float64)
                        measured = share / share.sum() * step_times[-1]
                    measured = measured * np.array(
                        [straggler.get(engine.stage_workers[s], 1.0)
                         for s in range(state.stages)])
                if injector is not None:
                    # chaos straggler spikes: same per-worker multiplier
                    # shape as the simulation knob above, sourced from the
                    # fault plan
                    mult = injector.spike_for(engine.stage_workers)
                    if mult is not None:
                        if measured is None:
                            share = np.asarray(state.lps, np.float64)
                            measured = share / share.sum() * step_times[-1]
                        measured = measured * np.asarray(mult)
                cp.publish(StatsSnapshot(
                    iteration=step + 1, epoch=engine.epoch,
                    stats=engine.stats_to_host(state, stats),
                    tags=np.asarray(state.assignment["tags"]),
                    num_micro=shapes.num_micro, tokens=tokens_per_step,
                    seq=seq, frozen=np.asarray(state.dyn["frozen"]),
                    stage_times=measured))
                if spec.controller.async_drain:
                    cp.drain()
                decide_s += time.perf_counter() - t_decide
                if sp_dec is not None:
                    sp_dec.end(source=src)

            # ---- cluster-scheduler directives (multi-tenant): a steal by
            # a higher-priority tenant arrives as a preemption directive
            # and is turned into an externally-originated ResizePlan — the
            # SAME epoch-fenced mailbox the controller uses, applied at
            # this step's safe point just below.  Level-triggered: if a
            # concurrent resize fences the injected plan off, the next poll
            # re-delivers the directive.
            if multi_tenant:
                from repro.cluster.rpc import JobManagerUnavailable
                try:
                    directives = jm.poll_cluster()
                except (JobManagerUnavailable, RuntimeError):
                    directives = None
                if directives and directives["preempt"] > 0:
                    target = max(tenant_min,
                                 state.stages - directives["preempt"])
                    if target < state.stages:
                        cp.inject_resize(engine.epoch, target)
                        last_cluster_resize = step
                        # the scheduler forwards the thief's span context
                        # ("cause"): parent this preemption on it so the
                        # cross-process steal→preempt→shrink chain
                        # correlates in the merged trace (DESIGN.md §15)
                        cause = (directives.get("cause")
                                 if isinstance(directives, dict) else None)
                        self._emit("preempt", step, cause_ctx=cause,
                                   due=directives["preempt"],
                                   target_stages=target)
                        if tracer is not None:
                            preempt_ctx = tracer.instant(
                                "cluster.preempt", cat="cluster",
                                parent_id=(cause or {}).get("span_id"),
                                cause_trace_id=(cause or {}).get(
                                    "trace_id"),
                                due=directives["preempt"],
                                target_stages=target)
                elif (directives and directives["offer"] > 0
                        and state.stages < stages
                        and step - last_cluster_resize >= absorb_cooldown):
                    prev = state.stages
                    state = engine.grow(
                        state, min(directives["offer"],
                                   stages - state.stages), step=step)
                    if state.stages > prev:   # scheduler may grant nothing
                        cp.with_ctrl(
                            lambda c: setattr(c.ccfg, "repack", False))
                        after_resize(step, "absorb")
                        self._emit("absorb", step,
                                   workers=state.stages - prev)
                        last_cluster_resize = step

            # ---- scripted voluntary shrink (tests/demos): same injection
            # point and mailbox as an external preemption, so a scripted
            # run is the loss-trajectory oracle for a stolen one
            if shrink_at and step in shrink_at \
                    and shrink_at[step] < state.stages:
                cp.inject_resize(engine.epoch, shrink_at[step],
                                 policy="scripted")

            # ---- safe point: apply the newest finished plan (epoch-
            # fenced; a plan decided against a pre-resize world is
            # rejected)
            plan = cp.poll(engine.epoch)
            if plan is not None:
                if plan.event is not None:
                    expert_skew_last = plan.event.expert_skew
                    moe_dropped_last = plan.event.expert_dropped
                if plan.event is not None and plan.event.rebalanced:
                    events.append(plan.event)
                    self._emit("rebalance", step,
                               iteration=plan.event.iteration,
                               imbalance_before=plan.event.imbalance_before,
                               imbalance_after=plan.event.imbalance_after,
                               moved_layers=plan.event.moved_layers)
                if (plan.resize is not None
                        and plan.resize.target_stages < state.stages):
                    sp_rz = None
                    if tracer is not None:
                        parent = ((preempt_ctx or {}).get("span_id")
                                  if plan.resize.policy == "preempt"
                                  else None)
                        sp_rz = tracer.span(
                            "resize.shrink", cat="resize",
                            parent_id=parent, step=step,
                            policy=plan.resize.policy,
                            target=plan.resize.target_stages)
                    state = engine.shrink(state, plan.resize.target_stages,
                                          plan.resize.layers_per_stage,
                                          step=step)
                    after_resize(step, f"shrink[{plan.resize.policy}]")
                    mreg.inc("dynmo_resizes_total", kind="shrink",
                             policy=plan.resize.policy,
                             help="engine resizes by kind")
                    if sp_rz is not None:
                        sp_rz.end(stages=state.stages)
                        if plan.resize.policy == "preempt":
                            preempt_ctx = None
                elif plan.new_lps is not None:
                    p, o, d, new_assignment, _ = cp.apply(
                        plan, state.params, state.opt_state, state.dyn)
                    state.params, state.opt_state, state.dyn = p, o, d
                    state.assignment = new_assignment
                    state.lps = list(cp.ctrl.lps)
                # ---- expert re-layout: orthogonal to the stage plans
                # above (it only rewrites the expert_map dyn leaf, which
                # survives a same-plan shrink because it is per-expert,
                # not per-stage)
                if (plan.expert_relayout is not None
                        and "expert_map" in state.dyn):
                    rl = plan.expert_relayout
                    dyn = dict(state.dyn)
                    em = dyn["expert_map"]
                    # broadcast the [E] placement over the existing sharded
                    # [S, L_max, E] leaf (em*0 + new keeps its placement;
                    # a fresh jnp array would land unsharded)
                    dyn["expert_map"] = em * 0 + jnp.asarray(
                        rl.new.as_array())
                    state.dyn = dyn
                    cp.with_ctrl(lambda c: c.commit_relayout(rl))
                    rec = {"step": step, "iteration": rl.iteration,
                           "skew": rl.skew, "tokens": rl.total_tokens,
                           "moved_experts": rl.moved_experts,
                           "placement": list(rl.new.placement)}
                    relayouts.append(rec)
                    self._emit("relayout", step, **rec)
                    print(f"step {step:4d} RELAYOUT skew "
                          f"{rl.skew:.2f} moved {rl.moved_experts} "
                          f"experts -> {list(rl.new.placement)}")

            # ---- autoscaler: heartbeat + watermark signals
            if scaler is not None:
                # "logical" clock: feed the watermark a schedule-derived
                # step time (GPipe tick count) instead of wall-clock —
                # deterministic on shared CI machines
                wm_dt = step_times[-1]
                if spec.cluster.watermark_clock == "logical":
                    wm_dt = engine.ticks(state.stages) * 1e-3
                d = scaler.observe(step, wm_dt, state.stages,
                                   engine.stage_workers, tokens_per_step)
                if d.action != "none":
                    self._emit("autoscale", step, action=d.action,
                               workers=d.workers, reason=d.reason,
                               ids=list(d.ids))
                if d.action == "evict":
                    state = engine.evict(state, d.ids, step=step)
                    after_resize(step, "evict")
                elif d.action == "grow" and state.stages < stages:
                    prev = state.stages
                    state = engine.grow(state, d.workers, step=step)
                    if state.stages > prev:   # pool may grant nothing
                        # granted workers stay for this job: stop planning
                        # resizes so ordinary rebalancing keeps running
                        cp.with_ctrl(
                            lambda c: setattr(c.ccfg, "repack", False))
                        after_resize(step, "grow")
                elif (d.action == "shrink"
                        and state.stages > max(1, repack_target)):
                    state = engine.shrink(
                        state, max(max(1, repack_target),
                                   state.stages - d.workers), step=step)
                    after_resize(step, "shrink[watermark]")

            # ---- legacy fixed-step growth (deprecated; superseded by
            # cluster.autoscale)
            if (grow_back and engine.last_shrink_step is not None
                    and state.stages < stages
                    and step >= engine.last_shrink_step + grow_back):
                prev_stages = state.stages
                state = engine.grow(state, stages - state.stages, step=step)
                if state.stages > prev_stages:
                    cp.with_ctrl(lambda c: setattr(c.ccfg, "repack", False))
                    after_resize(step, "grow")
            if ckpt:
                ckpt.maybe_save(step, state.params, state.opt_state,
                                state.dyn, state.lps)
            if safept is not None and safept.due(step):
                sp_ck = (tracer.span("safepoint", cat="checkpoint",
                                     step=step)
                         if tracer is not None else None)
                path = safept.save(
                    step, state, spec=spec, engine=engine, scaler=scaler,
                    repack_enabled=cp.with_ctrl(
                        lambda c: bool(c.ccfg.repack)),
                    jm_dir=self._jm_dir)
                if sp_ck is not None:
                    sp_ck.end(path=path)
                self._emit("safepoint", step, path=path,
                           stages=state.stages)
            if injector is not None:
                # fire scheduled faults AFTER the safe point: a trainer
                # kill at step k leaves the k-aligned safe point on disk
                # for Session.resume
                injector.on_step(step, workers=engine.stage_workers)
            if step % spec.log_every == 0:
                self._emit("log", step, loss=float(loss),
                           gnorm=float(gnorm), stages=state.stages,
                           lps=list(state.lps))
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"gnorm {float(gnorm):.3f} S={state.stages} "
                      f"lps={state.lps}")
        wall = time.perf_counter() - t0
        if root_span is not None:
            root_span.end(steps_run=len(losses))
        steady_s = float(sum(steady_times))
        steady_tok_s = (tokens_per_step * len(steady_times) / steady_s
                        if steady_s > 0 else None)
        if steady_tok_s is not None:
            mreg.set("dynmo_tokens_per_s", steady_tok_s,
                     help="steady-state training throughput")
        timing = {
            "warmup_steps": warmup_steps, "warmup_s": warmup_s,
            "decide_s": decide_s,
            "steady_steps": len(steady_times), "steady_s": steady_s,
            "steady_step_mean_s": (steady_s / len(steady_times)
                                   if steady_times else None),
            "steady_step_p50_s": (float(np.percentile(steady_times, 50))
                                  if steady_times else None),
            "steady_step_p95_s": (float(np.percentile(steady_times, 95))
                                  if steady_times else None),
            "steady_tokens_per_s": steady_tok_s,
        }
        report = {
            "losses": losses, "events": events, "wall_s": wall,
            "final_lps": list(state.lps), "params": state.params,
            "assignment": state.assignment,
            "tokens_per_step": tokens_per_step,
            "step_times": step_times, "stages_history": stages_hist,
            "resizes": [dataclasses.asdict(e) for e in engine.resizes],
            "pool_log": list(engine.jm.log),
            "final_stages": state.stages,
            "measured_stage_times": (list(map(float, last_measured))
                                     if last_measured is not None else None),
            "stage_time_source": stage_time_source,
            "timing": timing,
            "controller": {
                "mode": ("async" if spec.controller.async_decide
                         else "inline"),
                "published": cp.published, "decided": cp.decided,
                "dropped": cp.dropped,
                "stale_rejected": cp.stale_rejected},
            # ---- expert-parallel telemetry (MoE archs; None otherwise)
            "relayouts": relayouts,
            "expert_skew_last": expert_skew_last,
            "moe_dropped_last": moe_dropped_last,
            "expert_layout": (list(cp.ctrl.expert_layout.placement)
                              if cp.ctrl.expert_layout is not None
                              else None),
            "autoscale_decisions": ([dataclasses.asdict(d)
                                     for d in scaler.decisions]
                                    if scaler is not None else []),
            "spec": self.spec.to_dict(),
            # ---- fault-tolerance telemetry (DESIGN.md §12)
            "start_step": start_step,
            "resumed_from": (int(resume_idx["step"])
                             if resume_idx is not None else None),
            "safepoints": list(safept.saved) if safept is not None else [],
            "faults": injector.report() if injector is not None else [],
            "fault_plan": fplan.to_dict() if fplan is not None else None,
            "degraded_events": list(engine.degraded_events),
            "rpc": ({"stats": dict(jm.rpc_stats),
                     "breaker": jm.breaker.state_dict()}
                    if jm is not None else None),
        }
        self._emit("train_summary", steps - 1,
                   loss_first=losses[0] if losses else None,
                   loss_last=losses[-1] if losses else None,
                   wall_s=wall, resizes=len(engine.resizes),
                   final_stages=state.stages)
        return report

    # =======================================================================
    # Serving
    # =======================================================================
    def make_trace(self):
        """The request trace described by ``spec.serve`` (bursty square-wave
        arrivals, mixed prompt/gen lengths, optional early-exit fraction)."""
        from repro.serve import make_trace
        s = self.spec.serve
        cfg = self._model_config()
        return make_trace(s.requests, prompt_len=s.prompt_len,
                          max_gen=s.gen, vocab_size=cfg.vocab_size,
                          seed=self.spec.seed,
                          min_prompt=s.min_prompt or max(1,
                                                         s.prompt_len // 2),
                          burst_period=s.burst_period, burst_len=s.burst_len,
                          burst_rate=s.burst_rate, lull_rate=s.lull_rate,
                          early_exit_frac=s.early_exit_frac)

    def serve(self, trace=None, *, resize_at: Optional[Dict[int, int]] = None
              ) -> Dict[str, Any]:
        """Serve ``trace`` (default: the spec's generated trace) through the
        continuous-batching scheduler on elastic engine worlds.  Returns the
        server's report dict."""
        from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
        from repro.pipeline.pipeline import PipelineShapes
        from repro.serve import ElasticServer

        spec = self.spec
        s = spec.serve
        tracer = self._obs_begin("serve")
        cfg = self._model_config()
        dcfg = self._dist_config()
        dyncfg = spec.dynamics.to_config()
        shapes = PipelineShapes(spec.parallel.num_micro,
                                spec.parallel.mb_global, s.prompt_len,
                                cache_len=s.prompt_len + s.gen)
        paged = None
        if s.kv_page_size > 0:
            from repro.serve.kv import PagedKVConfig
            # kv_pool_pages=0 auto-sizes to the dense-equivalent footprint
            # (every lane could hold a full cache line) — same bytes as
            # dense, so paged-by-default changes layout, not capacity
            lanes = spec.parallel.num_micro * spec.parallel.mb_global
            pool = s.kv_pool_pages or lanes * (shapes.cache_len
                                               // s.kv_page_size)
            paged = PagedKVConfig(page_size=s.kv_page_size, pool_pages=pool,
                                  prefix_cache=s.prefix_cache)
        if trace is None:
            trace = self.make_trace()

        # ---- chaos: the fault horizon is the trace's expected drain time
        # (arrival span + tokens/lanes), not max_ticks — auto-derived events
        # must land while requests are actually in flight
        plan = injector = None
        if spec.faults.enabled:
            from repro.faults import ChaosInjector, resolve_plan
            lanes = spec.parallel.num_micro * spec.parallel.mb_global
            est = (max((r.arrival for r in trace), default=0)
                   + sum(r.gen for r in trace) // max(1, lanes)
                   + len(trace))
            plan = resolve_plan(spec.faults,
                                horizon=max(8, min(s.max_ticks, est)),
                                workers=spec.parallel.stages,
                                file_manager=spec.cluster.job_manager
                                == "file")
            injector = ChaosInjector(plan)
            self.injector = injector

        scaler = None
        if spec.cluster.autoscale:
            scaler = Autoscaler(AutoscalerConfig(
                min_stages=max(1, s.min_stages),
                max_stages=spec.parallel.stages,
                patience=s.patience, cooldown=s.cooldown,
                queue_high=s.queue_high, occupancy_low=s.occupancy_low,
                latency_slo_s=s.latency_slo_s))
        jm = self._connect_job_manager(plan=plan, injector=injector)
        # multi-tenant: start on the scheduler's grant (usually min_stages
        # — serve small, steal under load) instead of the spec's maximum
        granted = self._register_tenant(
            jm, kind="serve", workers=s.min_stages,
            max_workers=spec.parallel.stages, min_workers=s.min_stages)
        if injector is not None and spec.cluster.job_manager == "file":

            def _kill_manager():
                if self._jm_proc is not None:
                    self._jm_proc.kill()
                    self._jm_proc.wait()

            def _respawn_manager():
                from repro.cluster.rpc import spawn_file_manager
                self._jm_proc = spawn_file_manager(
                    self._jm_dir, spec.parallel.stages,
                    spares=spec.cluster.spares)

            injector.bind(kill_manager=_kill_manager,
                          respawn_manager=_respawn_manager)
        srv = ElasticServer(cfg, dcfg, dyncfg, shapes, job_manager=jm,
                            scaler=scaler, min_stages=s.min_stages,
                            seed=spec.seed, defrag_every=s.defrag_every,
                            measure_stage_times=spec.controller
                            .measure_stage_times,
                            initial_workers=granted,
                            in_step_timing=spec.obs.in_step_timing,
                            tracer=tracer, metrics=self.metrics,
                            paged=paged, temperature=s.temperature)
        self._server = srv
        root_span = (tracer.span("serve", cat="session",
                                 requests=len(trace))
                     if tracer is not None else None)
        report = srv.serve(trace, autoscale=spec.cluster.autoscale,
                           resize_at=resize_at, max_ticks=s.max_ticks,
                           injector=injector)
        if root_span is not None:
            root_span.end(ticks=report["ticks"],
                          completions=len(report["completions"]))
        self.metrics.set("dynmo_tokens_per_s", report["tokens_per_s"],
                         help="serving throughput")
        self.metrics.set("dynmo_latency_p95_s", report["latency_p95_s"],
                         help="serving p95 request latency")
        report["spec"] = spec.to_dict()
        report["faults"] = injector.report() if injector is not None else []
        report["fault_plan"] = plan.to_dict() if plan is not None else None
        report["degraded_events"] = list(srv.engine.degraded_events)
        report["rpc"] = ({"stats": dict(jm.rpc_stats),
                          "breaker": jm.breaker.state_dict()}
                         if jm is not None else None)
        for rz in report["resizes"]:
            self._emit("resize", rz["step"], resize_kind=rz["kind"],
                       from_stages=rz["from_stages"],
                       to_stages=rz["to_stages"],
                       workers=list(rz["workers"]))
            if granted is not None and rz["kind"] == "shrink":
                # tenant-scoped release IS a yield: the freed workers go
                # back through the scheduler to whoever is owed/offered
                self._emit("yield", rz["step"],
                           workers=list(rz["workers"]),
                           tenant=spec.cluster.tenant_id)
        for d in report["autoscale_decisions"]:
            self._emit("autoscale", d["step"], action=d["action"],
                       workers=d["workers"], reason=d["reason"],
                       ids=list(d["ids"]))
            if (granted is not None and d["action"] == "grow"
                    and d.get("urgent")):
                self._emit("steal", d["step"], workers=d["workers"],
                           reason=d["reason"],
                           tenant=spec.cluster.tenant_id)
        self._emit("serve_summary", report["ticks"],
                   completions=len(report["completions"]),
                   total_tokens=report["total_tokens"],
                   tokens_per_s=report["tokens_per_s"],
                   latency_p95_s=report["latency_p95_s"])
        return report
