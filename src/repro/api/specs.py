"""Typed, serializable run specification — the single front door.

A ``RunSpec`` is the complete description of one run of the system: model
shape, parallelism layout, dynamism scheme, controller policy, cluster
elasticity, and serving trace.  It is the unit that crosses every
boundary — CLI flags build one, ``--config run.json`` loads one, the
``Session`` executes one, scenario presets ship as checked-in ones, and
benchmark snapshots embed the one that produced each number.

Design rules (DESIGN.md §11):

  * **Frozen** — specs are values.  Derive variants with
    ``dataclasses.replace`` (or ``RunSpec.override`` for dotted paths).
  * **Validated at construction** — choice fields, ranges, and cross-field
    constraints (e.g. ``controller.repack.target < parallel.stages``) fail
    here with the dotted path in the message, not deep inside the engine.
  * **Strict deserialization** — unknown keys are errors, so a typo in a
    config file can never silently fall back to a default.
  * **Schema-versioned** — ``schema_version`` gates ``from_dict``; bumping
    it is a deliberate act covered by the golden-file test.

No jax imports here: loading or validating a spec never touches device
state.
"""

import dataclasses
import json
from typing import Any, Dict, List, Optional

from repro.configs.base import DTYPE_BYTES
from repro.dynamics.config import DynamicsConfig

SCHEMA_VERSION = 5

DYNAMISM_KINDS = ("none", "moe", "pruning", "freezing", "sparse_attention",
                  "early_exit", "mod")
KERNEL_IMPLS = ("reference", "scan", "pallas")
BALANCERS = ("diffusion", "partition")
REPACK_POLICIES = ("adjacent", "first_fit")
JOB_MANAGERS = ("inproc", "file", "http")


class SpecError(ValueError):
    """A spec failed validation; the message carries the dotted field path."""


def _check(cond: bool, path: str, msg: str) -> None:
    if not cond:
        raise SpecError(f"{path}: {msg}")


def _check_choice(value: str, choices, path: str) -> None:
    _check(value in choices, path,
           f"got {value!r}, expected one of {list(choices)}")


def _check_pos(value, path: str) -> None:
    _check(isinstance(value, int) and value >= 1, path,
           f"must be a positive int, got {value!r}")


def _check_frac(value, path: str) -> None:
    _check(0.0 <= float(value) <= 1.0, path,
           f"must be in [0, 1], got {value!r}")


# ---------------------------------------------------------------------------
# Leaf specs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Which architecture, optionally reduced to integration scale.

    ``layers=None`` runs the registry config at full size; setting it
    shrinks the arch via ``configs.base.reduced_config`` (family shape —
    MoE/SSM/enc-dec structure — is preserved)."""
    arch: str = "smollm-360m"
    layers: Optional[int] = None
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 2
    d_ff: Optional[int] = None        # None -> 2 * d_model
    vocab_size: int = 512

    def __post_init__(self):
        _check(isinstance(self.arch, str) and self.arch, "model.arch",
               f"must be a non-empty arch name, got {self.arch!r}")
        if self.layers is not None:
            _check_pos(self.layers, "model.layers")
        _check_pos(self.d_model, "model.d_model")
        _check_pos(self.num_heads, "model.num_heads")
        _check_pos(self.num_kv_heads, "model.num_kv_heads")
        if self.d_ff is not None:
            _check_pos(self.d_ff, "model.d_ff")
        _check_pos(self.vocab_size, "model.vocab_size")


@dataclasses.dataclass(frozen=True)
class ParallelSpec:
    """Pipeline / batch layout and kernel dispatch."""
    stages: int = 4
    num_micro: int = 4
    mb_global: int = 4
    seq: int = 64
    slot_slack: int = 2
    remat: str = "none"
    param_dtype: str = "float32"
    kernel_impl: str = "scan"
    data: int = 1

    def __post_init__(self):
        for name in ("stages", "num_micro", "mb_global", "seq", "data"):
            _check_pos(getattr(self, name), f"parallel.{name}")
        _check(isinstance(self.slot_slack, int) and self.slot_slack >= 0,
               "parallel.slot_slack",
               f"must be a non-negative int, got {self.slot_slack!r}")
        _check_choice(self.remat, ("none", "block", "full"), "parallel.remat")
        _check_choice(self.param_dtype, tuple(DTYPE_BYTES),
                      "parallel.param_dtype")
        _check_choice(self.kernel_impl, KERNEL_IMPLS, "parallel.kernel_impl")


@dataclasses.dataclass(frozen=True)
class DynamicsSpec:
    """Which dynamism scheme runs, wrapping ``dynamics.config.DynamicsConfig``
    field-for-field (same defaults) so the spec serializes what the jitted
    step will actually see."""
    kind: str = "none"
    # gradual pruning (Zhu–Gupta schedule, paper Eq. 3)
    prune_initial_sparsity: float = 0.0
    prune_final_sparsity: float = 0.9
    prune_start_iter: int = 3000
    prune_end_iter: int = 7000
    prune_frequency: int = 1000
    # layer freezing (Egeria-style)
    freeze_check_every: int = 50
    freeze_loss_slope_threshold: float = 0.02
    # dynamic sparse flash attention
    sparse_nbuckets: int = 8
    sparse_block: int = 512
    # early exit (CALM-style confidence)
    ee_threshold: float = 0.98
    ee_min_layer_frac: float = 0.25
    # mixture of depths
    mod_capacity: float = 0.5
    mod_every: int = 1
    # live expert re-layout (MoE archs, kernel_impl="pallas")
    expert_relayout: bool = False
    expert_watermark: float = 2.0
    expert_min_tokens: int = 16

    def __post_init__(self):
        _check_choice(self.kind, DYNAMISM_KINDS, "dynamics.kind")
        _check_frac(self.prune_initial_sparsity,
                    "dynamics.prune_initial_sparsity")
        _check_frac(self.prune_final_sparsity,
                    "dynamics.prune_final_sparsity")
        _check(self.prune_start_iter <= self.prune_end_iter,
               "dynamics.prune_start_iter",
               f"must be <= prune_end_iter ({self.prune_end_iter}), "
               f"got {self.prune_start_iter}")
        _check_frac(self.ee_threshold, "dynamics.ee_threshold")
        _check_frac(self.ee_min_layer_frac, "dynamics.ee_min_layer_frac")
        _check_frac(self.mod_capacity, "dynamics.mod_capacity")
        _check_pos(self.mod_every, "dynamics.mod_every")
        _check(float(self.expert_watermark) >= 1.0,
               "dynamics.expert_watermark",
               f"must be >= 1.0 (it is a max/mean load ratio), "
               f"got {self.expert_watermark!r}")
        _check(isinstance(self.expert_min_tokens, int)
               and self.expert_min_tokens >= 0,
               "dynamics.expert_min_tokens",
               f"must be a non-negative int, got {self.expert_min_tokens!r}")

    def to_config(self) -> DynamicsConfig:
        return DynamicsConfig(**{f.name: getattr(self, f.name)
                                 for f in dataclasses.fields(self)})


# Paper scenario presets at the DynamicsSpec level: the six example cases
# of §2 with their scheme-specific knobs at the paper's defaults.
# ``repro.api.scenarios`` composes these into full CI-runnable RunSpecs
# (arch + scale + controller); the JSON files under configs/scenarios/
# are their serialized form.
DYNAMICS_PRESETS: Dict[str, DynamicsSpec] = {
    kind: DynamicsSpec(kind=kind)
    for kind in DYNAMISM_KINDS if kind != "none"
}


@dataclasses.dataclass(frozen=True)
class RepackSpec:
    """Live worker consolidation (paper Alg. 2)."""
    enabled: bool = False
    policy: str = "adjacent"
    mem_cap: float = 1.1     # capacity factor x unpruned per-stage footprint
    target: int = 1          # never consolidate below this many workers

    def __post_init__(self):
        _check_choice(self.policy, REPACK_POLICIES, "controller.repack.policy")
        _check(self.mem_cap > 0, "controller.repack.mem_cap",
               f"must be > 0, got {self.mem_cap!r}")
        _check_pos(self.target, "controller.repack.target")


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """DynMo control loop: balancing policy, cadence, repack, stragglers."""
    balancer: str = "diffusion"
    rebalance_every: int = 10
    repack: RepackSpec = dataclasses.field(default_factory=RepackSpec)
    async_decide: bool = False    # profile->decide on a background thread
    async_drain: bool = False     # block per decision (deterministic async)
    straggler: Optional[Dict[int, float]] = None   # worker id -> slowdown
    measure_stage_times: bool = False

    def __post_init__(self):
        _check_choice(self.balancer, BALANCERS, "controller.balancer")
        _check_pos(self.rebalance_every, "controller.rebalance_every")
        if self.straggler is not None:
            for k, v in self.straggler.items():
                _check(isinstance(k, int) and k >= 0,
                       "controller.straggler",
                       f"worker ids must be ints >= 0, got {k!r}")
                _check(float(v) > 0, "controller.straggler",
                       f"multiplier for worker {k} must be > 0, got {v!r}")


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Elasticity across the job-manager boundary."""
    job_manager: str = "inproc"
    job_manager_dir: Optional[str] = None
    autoscale: bool = False
    autoscale_watermark: bool = False
    watermark_clock: str = "wall"   # "logical": schedule-derived step times
    #   (GPipe tick counts) feed the throughput watermark instead of
    #   wall-clock — deterministic, so --autoscale-watermark runs in CI
    heartbeat_timeout: float = 3.0
    rpc_timeout_s: float = 60.0   # file job-manager client: TOTAL retry
    #   budget per call — chaos/CI runs shrink it so degraded-mode paths
    #   (manager down, breaker open) don't stall a test for a minute
    simulate_recover: Optional[int] = None
    spares: int = 0   # fresh worker ids the pool may provision beyond the
    #   initial set — a post-crash grow can be granted a NEVER-seen process
    #   id instead of waiting for the dead machine to revive
    grow_back: Optional[int] = None   # DEPRECATED: fixed-step re-expansion
    # ---- multi-tenant scheduling (schema v3; DESIGN.md §14) ----
    tenant_id: Optional[str] = None   # register this Session as a tenant
    #   of a shared cluster scheduler; unset = legacy single-Session pool
    priority: int = 0   # steal arbitration rank: a steal only preempts
    #   STRICTLY lower-priority tenants
    manager_url: Optional[str] = None   # connect to an existing HTTP job
    #   manager instead of spawning one (two Sessions contending over one
    #   pool each point here); requires job_manager='http'

    def __post_init__(self):
        _check_choice(self.job_manager, JOB_MANAGERS, "cluster.job_manager")
        if self.tenant_id is not None:
            _check(isinstance(self.tenant_id, str) and self.tenant_id,
                   "cluster.tenant_id",
                   f"must be a non-empty string, got {self.tenant_id!r}")
        _check(isinstance(self.priority, int), "cluster.priority",
               f"must be an int, got {self.priority!r}")
        _check_choice(self.watermark_clock, ("wall", "logical"),
                      "cluster.watermark_clock")
        _check(self.heartbeat_timeout > 0, "cluster.heartbeat_timeout",
               f"must be > 0, got {self.heartbeat_timeout!r}")
        _check(self.spares >= 0, "cluster.spares",
               f"must be >= 0, got {self.spares!r}")
        _check(self.rpc_timeout_s > 0, "cluster.rpc_timeout_s",
               f"must be > 0, got {self.rpc_timeout_s!r}")
        if self.simulate_recover is not None:
            _check(self.simulate_recover >= 0, "cluster.simulate_recover",
                   f"must be >= 0, got {self.simulate_recover!r}")
        if self.grow_back is not None:
            _check_pos(self.grow_back, "cluster.grow_back")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Deterministic chaos schedule (new in schema v2; DESIGN.md §12).

    ``enabled`` turns the ``faults.ChaosInjector`` on; ``auto`` derives a
    seeded random schedule from ``seed`` (``faults.plan.resolve_plan``) and
    merges it under any explicitly pinned fields below.  Steps are trainer
    steps (train) or scheduler ticks (serve); probabilities are per-RPC.
    """
    enabled: bool = False
    seed: int = 0
    auto: bool = False
    worker_crash: Optional[Dict[int, int]] = None   # step/tick -> worker id
    manager_kill: Optional[int] = None              # kill -9 the jm server
    manager_respawn: Optional[int] = None           # restart it on same dir
    kill_at: Optional[int] = None                   # SIGKILL the trainer
    rpc_loss: float = 0.0                           # drop a request write
    rpc_dup: float = 0.0                            # duplicate a delivery
    rpc_delay_s: float = 0.0                        # per-message max delay
    straggler_spike: Optional[Dict[int, float]] = None  # step -> multiplier

    def __post_init__(self):
        _check(isinstance(self.seed, int), "faults.seed",
               f"must be an int, got {self.seed!r}")
        for name in ("rpc_loss", "rpc_dup"):
            _check_frac(getattr(self, name), f"faults.{name}")
        _check(self.rpc_delay_s >= 0, "faults.rpc_delay_s",
               f"must be >= 0, got {self.rpc_delay_s!r}")
        for name in ("manager_kill", "manager_respawn", "kill_at"):
            v = getattr(self, name)
            if v is not None:
                _check(isinstance(v, int) and v >= 0, f"faults.{name}",
                       f"must be a step index >= 0, got {v!r}")
        if self.worker_crash is not None:
            for k, v in self.worker_crash.items():
                _check(isinstance(k, int) and k >= 0, "faults.worker_crash",
                       f"keys must be steps >= 0, got {k!r}")
                _check(isinstance(v, int) and v >= 0, "faults.worker_crash",
                       f"values must be worker ids >= 0, got {v!r}")
        if self.straggler_spike is not None:
            for k, v in self.straggler_spike.items():
                _check(isinstance(k, int) and k >= 0,
                       "faults.straggler_spike",
                       f"keys must be steps >= 0, got {k!r}")
                _check(float(v) > 0, "faults.straggler_spike",
                       f"multiplier at step {k} must be > 0, got {v!r}")

    @property
    def any_rpc(self) -> bool:
        return bool(self.rpc_loss or self.rpc_dup or self.rpc_delay_s)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Request trace, KV-slot shapes, and load-signal SLOs for serving."""
    requests: int = 16
    prompt_len: int = 32
    gen: int = 8
    min_prompt: Optional[int] = None
    burst_period: int = 0
    burst_len: int = 0
    burst_rate: int = 4
    lull_rate: int = 1
    early_exit_frac: float = 0.0
    defrag_every: int = 0
    min_stages: int = 1
    queue_high: int = 8
    occupancy_low: float = 0.35
    patience: int = 2
    cooldown: int = 4
    latency_slo_s: float = 0.0
    max_ticks: int = 100000
    # ---- paged KV memory (schema v5; DESIGN.md §16) ----
    kv_page_size: int = 0     # tokens per KV block; 0 = dense contiguous
    #   lanes (the paged subsystem entirely off)
    kv_pool_pages: int = 0    # physical blocks in the pool; 0 = auto-size
    #   to the dense-equivalent footprint (lanes x pages-per-lane)
    prefix_cache: bool = False   # refcounted copy-on-write sharing of full
    #   prompt pages across requests with a common prefix
    temperature: float = 0.0     # per-lane decode sampling; 0 = argmax
    #   (bit-exact with every pre-v5 run)

    def __post_init__(self):
        for name in ("requests", "prompt_len", "gen", "min_stages",
                     "max_ticks"):
            _check_pos(getattr(self, name), f"serve.{name}")
        if self.min_prompt is not None:
            _check_pos(self.min_prompt, "serve.min_prompt")
            _check(self.min_prompt <= self.prompt_len, "serve.min_prompt",
                   f"must be <= prompt_len ({self.prompt_len}), "
                   f"got {self.min_prompt}")
        for name in ("burst_period", "burst_len", "burst_rate", "lull_rate",
                     "defrag_every", "queue_high", "patience", "cooldown",
                     "kv_page_size", "kv_pool_pages"):
            v = getattr(self, name)
            _check(isinstance(v, int) and v >= 0, f"serve.{name}",
                   f"must be a non-negative int, got {v!r}")
        _check_frac(self.early_exit_frac, "serve.early_exit_frac")
        _check_frac(self.occupancy_low, "serve.occupancy_low")
        _check(self.latency_slo_s >= 0, "serve.latency_slo_s",
               f"must be >= 0, got {self.latency_slo_s!r}")
        _check(self.temperature >= 0, "serve.temperature",
               f"must be >= 0, got {self.temperature!r}")
        if self.kv_page_size > 0:
            # the cache line (prompt_len + gen positions, what the session
            # sizes cache_len to) must tile exactly into pages: a paged
            # lane row then has the same logical length as the dense line,
            # which is what keeps the attention reduction bit-exact
            _check((self.prompt_len + self.gen) % self.kv_page_size == 0,
                   "serve.kv_page_size",
                   f"must divide prompt_len + gen "
                   f"({self.prompt_len + self.gen}), got {self.kv_page_size}")
        else:
            _check(not self.prefix_cache, "serve.prefix_cache",
                   "requires the paged KV subsystem (serve.kv_page_size > 0)")
            _check(self.kv_pool_pages == 0, "serve.kv_pool_pages",
                   "requires serve.kv_page_size > 0")


@dataclasses.dataclass(frozen=True)
class ObsSpec:
    """Observability (new in schema v4; DESIGN.md §15).

    Everything here is inert by default: no tracer, no metrics endpoint,
    stage timings still come from the probe.  ``in_step_timing`` switches
    ``StatsSnapshot.stage_times`` to the live in-step stamps (the probe
    stays available behind ``controller.measure_stage_times`` as a parity
    oracle)."""
    trace: bool = False               # record spans (Tracer) for this run
    trace_out: Optional[str] = None   # export Chrome trace-event JSON here
    metrics_port: Optional[int] = None   # serve GET /metrics on this port
    metrics_out: Optional[str] = None    # write a JSON metrics snapshot
    in_step_timing: bool = False      # stage times from the live step

    def __post_init__(self):
        if self.metrics_port is not None:
            _check(isinstance(self.metrics_port, int)
                   and 0 < self.metrics_port < 65536,
                   "obs.metrics_port",
                   f"must be a port in (0, 65536), got {self.metrics_port!r}")
        if self.trace_out is not None:
            _check(isinstance(self.trace_out, str) and self.trace_out,
                   "obs.trace_out",
                   f"must be a non-empty path, got {self.trace_out!r}")
        if self.metrics_out is not None:
            _check(isinstance(self.metrics_out, str) and self.metrics_out,
                   "obs.metrics_out",
                   f"must be a non-empty path, got {self.metrics_out!r}")


# ---------------------------------------------------------------------------
# The composed spec
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One run of the system, end to end."""
    schema_version: int = SCHEMA_VERSION
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    parallel: ParallelSpec = dataclasses.field(default_factory=ParallelSpec)
    dynamics: DynamicsSpec = dataclasses.field(default_factory=DynamicsSpec)
    controller: ControllerSpec = dataclasses.field(
        default_factory=ControllerSpec)
    cluster: ClusterSpec = dataclasses.field(default_factory=ClusterSpec)
    serve: ServeSpec = dataclasses.field(default_factory=ServeSpec)
    faults: FaultSpec = dataclasses.field(default_factory=FaultSpec)
    obs: ObsSpec = dataclasses.field(default_factory=ObsSpec)
    steps: int = 50
    seed: int = 0
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0       # >0: safe-point checkpoint cadence (steps)

    # -- validation --------------------------------------------------------
    def __post_init__(self):
        _check(self.schema_version == SCHEMA_VERSION, "schema_version",
               f"this build reads schema v{SCHEMA_VERSION}, the spec says "
               f"v{self.schema_version}; migrate the config (DESIGN.md §11)")
        _check_pos(self.steps, "steps")
        _check(isinstance(self.seed, int), "seed",
               f"must be an int, got {self.seed!r}")
        _check_pos(self.log_every, "log_every")
        # cross-field constraints: fail at construction, not in the engine
        if self.controller.repack.enabled:
            _check(self.controller.repack.target < self.parallel.stages,
                   "controller.repack.target",
                   f"must be < parallel.stages ({self.parallel.stages}) "
                   f"when repack is enabled, got "
                   f"{self.controller.repack.target}")
        _check(self.serve.min_stages <= self.parallel.stages,
               "serve.min_stages",
               f"must be <= parallel.stages ({self.parallel.stages}), "
               f"got {self.serve.min_stages}")
        if self.cluster.simulate_recover is not None:
            _check(self.cluster.autoscale, "cluster.simulate_recover",
                   "requires cluster.autoscale=true (heartbeat recovery is "
                   "an autoscaler signal)")
        if self.cluster.manager_url is not None:
            _check(self.cluster.job_manager == "http",
                   "cluster.manager_url",
                   "connecting to an existing manager requires "
                   "cluster.job_manager='http'")
        if self.cluster.tenant_id is not None:
            _check(self.cluster.job_manager != "inproc",
                   "cluster.tenant_id",
                   "tenant registration needs a shared manager process; "
                   "cluster.job_manager must be 'file' or 'http'")
        if self.cluster.autoscale_watermark:
            _check(self.cluster.autoscale, "cluster.autoscale_watermark",
                   "requires cluster.autoscale=true")
        if self.controller.straggler:
            for k in self.controller.straggler:
                _check(k < self.parallel.stages, "controller.straggler",
                       f"worker id {k} out of range for parallel.stages="
                       f"{self.parallel.stages}")
        _check(isinstance(self.ckpt_every, int) and self.ckpt_every >= 0,
               "ckpt_every",
               f"must be a non-negative int, got {self.ckpt_every!r}")
        if self.ckpt_every:
            _check(bool(self.ckpt_dir), "ckpt_every",
                   "requires ckpt_dir (safe-point checkpoints need a "
                   "directory to land in)")
        if self.faults.enabled:
            f = self.faults
            if f.manager_kill is not None or f.manager_respawn is not None:
                _check(self.cluster.job_manager == "file",
                       "faults.manager_kill",
                       "killing the job-manager process requires "
                       "cluster.job_manager='file' (inproc has no process "
                       "to kill)")
            if f.manager_kill is not None and f.manager_respawn is not None:
                _check(f.manager_respawn > f.manager_kill,
                       "faults.manager_respawn",
                       f"must be > manager_kill ({f.manager_kill}), got "
                       f"{f.manager_respawn}")
            if f.any_rpc:
                _check(self.cluster.job_manager == "file", "faults.rpc_loss",
                       "RPC loss/dup/delay faults act on the file "
                       "transport; cluster.job_manager must be 'file'")
            if f.kill_at is not None:
                _check(self.ckpt_every > 0, "faults.kill_at",
                       "killing the trainer without ckpt_every > 0 loses "
                       "the run — enable safe-point checkpoints")

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return _to_dict(self)

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=False)

    @classmethod
    def from_dict(cls, d: Dict[str, Any], source: str = "spec") -> "RunSpec":
        _check(isinstance(d, dict), source,
               f"expected a JSON object, got {type(d).__name__}")
        ver = d.get("schema_version", SCHEMA_VERSION)
        _check(isinstance(ver, int), f"{source}.schema_version",
               f"must be an int, got {ver!r}")
        _check(ver <= SCHEMA_VERSION, f"{source}.schema_version",
               f"this build reads schema v{SCHEMA_VERSION}, the file says "
               f"v{ver}; migrate the config (DESIGN.md §11)")
        while ver < SCHEMA_VERSION:
            _check(ver in _UPGRADERS, f"{source}.schema_version",
                   f"no upgrader registered for schema v{ver}")
            d = _UPGRADERS[ver](dict(d))
            _check(d.get("schema_version") == ver + 1,
                   f"{source}.schema_version",
                   f"upgrader v{ver} did not bump the version")
            ver += 1
        return _from_dict(cls, d, source)

    @classmethod
    def from_json(cls, text: str, source: str = "spec") -> "RunSpec":
        try:
            d = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"{source}: not valid JSON: {e}") from None
        return cls.from_dict(d, source)

    @classmethod
    def load(cls, path: str) -> "RunSpec":
        with open(path) as f:
            return cls.from_json(f.read(), source=path)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # -- dotted-path access (CLI --set, flag builder) ----------------------
    def get(self, path: str) -> Any:
        node: Any = self
        for part in path.split("."):
            _check(dataclasses.is_dataclass(node)
                   and part in {f.name for f in dataclasses.fields(node)},
                   path, f"unknown field {part!r}")
            node = getattr(node, part)
        return node

    def override(self, assignments: Dict[str, Any]) -> "RunSpec":
        """Return a new spec with dotted-path overrides applied, e.g.
        ``{"controller.repack.policy": "first_fit"}`` — the typed engine
        behind CLI ``--set``.  Values are coerced to the field type."""
        d = self.to_dict()
        for path, value in assignments.items():
            parts = path.split(".")
            ftype = leaf_field_type(path)   # raises SpecError on bad path
            node = d
            for part in parts[:-1]:
                node = node[part]
            node[parts[-1]] = coerce_value(value, ftype, path)
        return RunSpec.from_dict(d, source="override")


# ---------------------------------------------------------------------------
# Schema migrations: one pure dict->dict upgrader per historical version.
# ``from_dict`` chains them, so a v1 config keeps loading forever and the
# golden-fixture test pins each frozen version's file byte-for-byte.
# ---------------------------------------------------------------------------
def _upgrade_v1(d: Dict[str, Any]) -> Dict[str, Any]:
    """v1 -> v2: adds ``faults`` (FaultSpec) and ``ckpt_every``.  Both are
    new knobs with inert defaults, so the upgrade is purely additive —
    a v1 run means exactly the same v2 run."""
    d["schema_version"] = 2
    d.setdefault("faults", {})
    d.setdefault("ckpt_every", 0)
    return d


def _upgrade_v2(d: Dict[str, Any]) -> Dict[str, Any]:
    """v2 -> v3: multi-tenant cluster scheduling (DESIGN.md §14) — adds
    ``cluster.tenant_id`` / ``cluster.priority`` / ``cluster.manager_url``
    and the 'http' job-manager choice.  All inert by default (no tenant id
    = legacy single-Session pool), so the upgrade is purely additive."""
    d["schema_version"] = 3
    c = d.setdefault("cluster", {})
    if isinstance(c, dict):
        c.setdefault("tenant_id", None)
        c.setdefault("priority", 0)
        c.setdefault("manager_url", None)
    return d


def _upgrade_v3(d: Dict[str, Any]) -> Dict[str, Any]:
    """v3 -> v4: the observability layer (DESIGN.md §15) — adds the
    ``obs`` block (tracing, metrics endpoint, in-step stage timing).  All
    off by default, so the upgrade is purely additive."""
    d["schema_version"] = 4
    d.setdefault("obs", {})
    return d


def _upgrade_v4(d: Dict[str, Any]) -> Dict[str, Any]:
    """v4 -> v5: the paged KV memory subsystem (DESIGN.md §16) — adds
    ``serve.kv_page_size`` / ``serve.kv_pool_pages`` / ``serve.prefix_cache``
    and per-lane ``serve.temperature``.  Defaults keep serving dense and
    argmax, so a v4 run means exactly the same (bit-exact) v5 run."""
    d["schema_version"] = 5
    s = d.setdefault("serve", {})
    if isinstance(s, dict):
        s.setdefault("kv_page_size", 0)
        s.setdefault("kv_pool_pages", 0)
        s.setdefault("prefix_cache", False)
        s.setdefault("temperature", 0.0)
    return d


_UPGRADERS = {1: _upgrade_v1, 2: _upgrade_v2, 3: _upgrade_v3,
              4: _upgrade_v4}


# ---------------------------------------------------------------------------
# dict <-> dataclass plumbing (strict: unknown keys are errors)
# ---------------------------------------------------------------------------
def _to_dict(spec) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for f in dataclasses.fields(spec):
        v = getattr(spec, f.name)
        if dataclasses.is_dataclass(v):
            out[f.name] = _to_dict(v)
        elif isinstance(v, dict):
            # JSON object keys are strings; from_dict coerces them back
            out[f.name] = {str(k): vv for k, vv in v.items()}
        else:
            out[f.name] = v
    return out


# int-keyed dict fields (JSON stringifies keys; from_dict coerces back):
# (owner class, field name) -> value coercion
_INT_KEY_DICTS = {
    ("ControllerSpec", "straggler"): float,
    ("FaultSpec", "worker_crash"): int,
    ("FaultSpec", "straggler_spike"): float,
}


def _from_dict(cls, d: Dict[str, Any], path: str):
    fields = {f.name: f for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - set(fields))
    if unknown:
        raise SpecError(
            f"{path}: unknown key{'s' if len(unknown) > 1 else ''} "
            f"{unknown}; known keys: {sorted(fields)}")
    kwargs: Dict[str, Any] = {}
    for name, f in fields.items():
        if name not in d:
            continue
        v = d[name]
        val_t = _INT_KEY_DICTS.get((cls.__name__, name))
        if dataclasses.is_dataclass(f.type):
            _check(isinstance(v, dict), f"{path}.{name}",
                   f"expected a JSON object, got {type(v).__name__}")
            v = _from_dict(f.type, v, f"{path}.{name}")
        elif val_t is not None and v is not None:
            _check(isinstance(v, dict), f"{path}.{name}",
                   f"expected a JSON object, got {type(v).__name__}")
            try:
                v = {int(k): val_t(vv) for k, vv in v.items()}
            except (TypeError, ValueError):
                raise SpecError(
                    f"{path}.{name}: keys must be ints, values "
                    f"{val_t.__name__}s; got {v!r}") from None
        kwargs[name] = v
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Field reflection for the CLI flag builder
# ---------------------------------------------------------------------------
def leaf_fields(cls=RunSpec, prefix: str = "") -> List[Any]:
    """Yield (dotted_path, field) for every scalar leaf of the spec tree."""
    out = []
    for f in dataclasses.fields(cls):
        path = f"{prefix}{f.name}"
        if dataclasses.is_dataclass(f.type):
            out.extend(leaf_fields(f.type, prefix=f"{path}."))
        else:
            out.append((path, f))
    return out


_LEAF_TYPES = {path: f for path, f in leaf_fields()}


def leaf_field_type(path: str):
    if path not in _LEAF_TYPES:
        near = sorted(p for p in _LEAF_TYPES
                      if p.split(".")[-1] == path.split(".")[-1])
        hint = f"; did you mean {near}?" if near else ""
        raise SpecError(f"{path}: not a spec field{hint}")
    return _LEAF_TYPES[path].type


def coerce_value(value: Any, ftype, path: str) -> Any:
    """Coerce a CLI/JSON-supplied value to a leaf field's declared type.
    Strings parse per the type ("none"/"null" -> None for Optionals)."""
    origin = getattr(ftype, "__origin__", None)
    args = getattr(ftype, "__args__", ())
    optional = origin is not None and type(None) in args
    if optional:
        inner = [a for a in args if a is not type(None)]
        if value is None or (isinstance(value, str)
                             and value.lower() in ("none", "null")):
            return None
        ftype = inner[0] if len(inner) == 1 else str
        origin = getattr(ftype, "__origin__", None)
    if origin is dict:   # e.g. controller.straggler: "2:1.5,3:1.2" or a dict
        dict_args = getattr(ftype, "__args__", ())
        val_t = dict_args[1] if len(dict_args) == 2 else float
        if isinstance(value, dict):
            return {int(k): val_t(v) for k, v in value.items()}
        try:
            return {int(k): val_t(v) for k, v in
                    (part.split(":") for part in str(value).split(","))}
        except ValueError:
            raise SpecError(
                f"{path}: expected 'key:value[,key:value...]', "
                f"got {value!r}") from None
    if ftype is bool:
        if isinstance(value, bool):
            return value
        s = str(value).lower()
        if s in ("1", "true", "yes", "on"):
            return True
        if s in ("0", "false", "no", "off"):
            return False
        raise SpecError(f"{path}: expected a bool, got {value!r}")
    if ftype is int:
        if isinstance(value, bool):
            raise SpecError(f"{path}: expected an int, got {value!r}")
        try:
            return int(value)
        except (TypeError, ValueError):
            raise SpecError(
                f"{path}: expected an int, got {value!r}") from None
    if ftype is float:
        try:
            return float(value)
        except (TypeError, ValueError):
            raise SpecError(
                f"{path}: expected a float, got {value!r}") from None
    return str(value)
