"""Registry of the paper's six dynamism scenarios as runnable ``RunSpec``s.

Each preset is the §2 example case at CPU integration scale (4 forced host
devices, reduced arch) so `python -m repro.launch.train --config
configs/scenarios/<name>.json` demonstrates the scheme end-to-end in CI.
The checked-in JSON files under ``configs/scenarios/`` are exactly these
specs serialized (``scripts/gen_scenarios.py`` regenerates them;
``scripts/check_configs.py`` and the CI config-check step keep them honest).

``moe`` runs a real MoE family arch (routing imbalance is intrinsic — no
dynamism events needed); the other five run the reduced dense GPT with the
scheme's dyn-state mutations driven by the training loop.
"""
import dataclasses
from typing import Dict, List

from repro.api.specs import (DYNAMICS_PRESETS, ControllerSpec, ModelSpec,
                             ParallelSpec, RepackSpec, RunSpec)

# one shared integration scale: big enough that rebalancing has layers to
# move (8 blocks over 4 stages), small enough for a CI matrix job
_PARALLEL = ParallelSpec(stages=4, num_micro=2, mb_global=2, seq=32)
_MODEL = ModelSpec(arch="smollm-360m", layers=8, d_model=64)
_CONTROLLER = ControllerSpec(rebalance_every=5)


def _spec(**kw) -> RunSpec:
    base = dict(model=_MODEL, parallel=_PARALLEL, controller=_CONTROLLER,
                steps=16, log_every=5)
    base.update(kw)
    return RunSpec(**base)


SCENARIOS: Dict[str, RunSpec] = {
    # MoE: routing imbalance is intrinsic to the arch; the controller sees
    # it through the per-slot stats like any other cost skew
    "moe": _spec(model=ModelSpec(arch="mixtral-8x7b", layers=4, d_model=64),
                 dynamics=DYNAMICS_PRESETS["moe"]),
    # gradual block pruning (Zhu–Gupta) + live repack: the model shrinks
    # until the controller consolidates 4 workers onto fewer (Alg. 2)
    "pruning": _spec(
        dynamics=DYNAMICS_PRESETS["pruning"],
        controller=dataclasses.replace(
            _CONTROLLER, repack=RepackSpec(enabled=True)),
        steps=26),
    # Egeria-style front-to-back freezing: frozen layers drop their
    # backward cost and the balancer shifts layers toward them
    "freezing": _spec(dynamics=DYNAMICS_PRESETS["freezing"], steps=26),
    # dynamic sparse flash attention; bucket/block sizes shrunk so the
    # hash mask actually fires at integration seq length
    "sparse_attention": _spec(dynamics=dataclasses.replace(
        DYNAMICS_PRESETS["sparse_attention"],
        sparse_block=16, sparse_nbuckets=4)),
    # CALM-style early exit: confident tokens stop flowing through the
    # deeper stages
    "early_exit": _spec(dynamics=DYNAMICS_PRESETS["early_exit"]),
    # mixture-of-depths routing around every block
    "mod": _spec(dynamics=DYNAMICS_PRESETS["mod"]),
}


def scenario_names() -> List[str]:
    return sorted(SCENARIOS)


def scenario(name: str) -> RunSpec:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {scenario_names()}")
    return SCENARIOS[name]
