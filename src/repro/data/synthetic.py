"""Synthetic corpora: Zipfian token streams with local structure (Markov
bigram flavor) so small models show real loss descent, plus a tiny embedded
text corpus for tokenizer round-trips.  Deterministic by seed."""
from __future__ import annotations

from typing import Iterator

import numpy as np

_TEXT = (
    "the quick brown fox jumps over the lazy dog . "
    "pipeline parallel training of dynamic language models introduces "
    "load imbalance across workers . dynmo rebalances layers between "
    "stages whenever the workload drifts , and re-packs the model onto "
    "fewer accelerators when the total work shrinks . "
) * 64


def synthetic_corpus() -> str:
    return _TEXT


def zipf_token_stream(vocab_size: int, seed: int = 0, alpha: float = 1.1,
                      block: int = 1 << 16) -> Iterator[np.ndarray]:
    """Endless stream of token blocks with Zipf marginals and bigram
    structure (each token biases the next toward a deterministic successor,
    giving the model something learnable)."""
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = ranks ** -alpha
    probs /= probs.sum()
    succ = rng.permutation(vocab_size)
    while True:
        base = rng.choice(vocab_size, size=block, p=probs)
        coin = rng.rand(block) < 0.35
        out = base.copy()
        out[1:][coin[1:]] = succ[out[:-1][coin[1:]]]
        yield out.astype(np.int32)
