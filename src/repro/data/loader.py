"""Sharded batch loader: shapes batches as the pipeline wants them —
[num_micro, mb_global, seq] token/label arrays (+ stub modality inputs),
deterministically resumable (step-indexed), with next-token labels."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import zipf_token_stream


@dataclasses.dataclass
class DataConfig:
    num_micro: int
    mb_global: int
    seq: int
    seed: int = 0


def make_loader(cfg: ModelConfig, dc: DataConfig, start_step: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
    """Yields batches; resumable by constructing with start_step."""
    need = dc.num_micro * dc.mb_global * (dc.seq + 1)
    stream = zipf_token_stream(cfg.vocab_size, seed=dc.seed,
                               block=max(1 << 16, need))
    buf = np.empty(0, np.int32)
    step = 0
    for blockarr in stream:
        buf = np.concatenate([buf, blockarr])
        while len(buf) >= need:
            chunk, buf = buf[:need], buf[need:]
            if step >= start_step:
                toks = chunk.reshape(dc.num_micro, dc.mb_global, dc.seq + 1)
                batch = {
                    "tokens": toks[..., :-1],
                    "labels": toks[..., 1:],
                    "label_mask": np.ones(
                        (dc.num_micro, dc.mb_global, dc.seq), np.float32),
                }
                if cfg.family == "vlm":
                    rng = np.random.RandomState(dc.seed * 9973 + step)
                    batch["prefix_emb"] = rng.randn(
                        dc.num_micro, dc.mb_global, cfg.num_patches,
                        cfg.d_model).astype(np.float32) * 0.05
                if cfg.is_encdec:
                    rng = np.random.RandomState(dc.seed * 7919 + step)
                    batch["frames"] = rng.randn(
                        dc.num_micro, dc.mb_global, cfg.encoder_seq,
                        cfg.d_model).astype(np.float32) * 0.05
                yield batch
            step += 1
