"""Byte-level tokenizer with a small learned-free BPE-ish merge table option.

Offline container ⇒ no external vocabs; byte fallback keeps any text valid.
Vocab layout: [0..255] bytes, 256 = BOS, 257 = EOS, 258 = PAD, then merges.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, Iterable, List, Sequence, Tuple

BOS, EOS, PAD = 256, 257, 258
BASE = 259


class ByteTokenizer:
    def __init__(self, merges: Sequence[Tuple[int, int]] = ()):
        self.merges: List[Tuple[int, int]] = list(merges)
        self._ranks: Dict[Tuple[int, int], int] = {
            m: i for i, m in enumerate(self.merges)}

    @property
    def vocab_size(self) -> int:
        return BASE + len(self.merges)

    @classmethod
    def train(cls, texts: Iterable[str], num_merges: int = 256
              ) -> "ByteTokenizer":
        corpus = [list(t.encode("utf-8")) for t in texts]
        merges: List[Tuple[int, int]] = []
        for step in range(num_merges):
            pairs = Counter()
            for seq in corpus:
                pairs.update(zip(seq, seq[1:]))
            if not pairs:
                break
            (a, b), cnt = pairs.most_common(1)[0]
            if cnt < 2:
                break
            tok = BASE + len(merges)
            merges.append((a, b))
            corpus = [cls._merge_seq(s, a, b, tok) for s in corpus]
        return cls(merges)

    @staticmethod
    def _merge_seq(seq, a, b, tok):
        out, i = [], 0
        while i < len(seq):
            if i + 1 < len(seq) and seq[i] == a and seq[i + 1] == b:
                out.append(tok)
                i += 2
            else:
                out.append(seq[i])
                i += 1
        return out

    def encode(self, text: str, bos: bool = True, eos: bool = False
               ) -> List[int]:
        seq = list(text.encode("utf-8"))
        for i, (a, b) in enumerate(self.merges):
            seq = self._merge_seq(seq, a, b, BASE + i)
        return ([BOS] if bos else []) + seq + ([EOS] if eos else [])

    def decode(self, ids: Sequence[int]) -> str:
        rev: Dict[int, Tuple[int, int]] = {
            BASE + i: m for i, m in enumerate(self.merges)}

        def expand(t):
            if t < 256:
                return [t]
            if t in rev:
                a, b = rev[t]
                return expand(a) + expand(b)
            return []
        out: List[int] = []
        for t in ids:
            out.extend(expand(int(t)))
        return bytes(out).decode("utf-8", errors="replace")
