from repro.data.loader import DataConfig, make_loader
from repro.data.tokenizer import ByteTokenizer
from repro.data.synthetic import synthetic_corpus, zipf_token_stream

__all__ = ["DataConfig", "make_loader", "ByteTokenizer", "synthetic_corpus",
           "zipf_token_stream"]
