"""Chaos injection runtime: fires a ``FaultPlan`` into a live run.

``ChaosInjector`` is transport for the plan only — it owns NO runtime
objects.  The ``Session`` (or a test) binds callbacks for the actions that
need privileged access (killing the manager process, SIGKILLing the
trainer, crashing a serving worker), and the injector fires them at the
scheduled steps, recording every injected fault into ``records`` (the
fault-event log the chaos CI job uploads).

Worker crashes in *training* need no callback: the injector simply stops
the worker from heartbeating (``heartbeat_workers`` filters it), and the
ordinary ``HeartbeatMonitor`` → ``Autoscaler`` → ``engine.evict`` pipeline
does the rest — chaos exercises the REAL failure path, it does not
simulate its effects.

``ChaosFileJobManager`` wraps the file RPC transport with seeded message
loss / duplication / delay: a lost request is simply never written (the
client's retry re-publishes the same sequence number), a duplicated one is
re-delivered after the server already answered (exercising server-side
dedup), a delayed one sleeps before the write.  All rolls come from one
seeded stream, so a chaos run is reproducible per seed.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

from repro.cluster.rpc import FileJobManager
from repro.faults.plan import FaultEvent, FaultPlan


@dataclasses.dataclass
class FaultRecord:
    """One injected fault.  Since schema v4 the dict form (``report()``)
    additionally carries the unified event fields — schema/source/wall and
    tracing identity when a tracer is current (DESIGN.md §15); the legacy
    ``step``/``kind``/``detail`` triple is unchanged."""
    step: int
    kind: str
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)
    obs: Dict[str, Any] = dataclasses.field(default_factory=dict)


class ChaosInjector:
    def __init__(self, plan: FaultPlan, *, start_step: int = 0,
                 resumed: bool = False):
        self.plan = plan
        self.records: List[FaultRecord] = []
        self.crashed: Set[int] = set()
        self._cbs: Dict[str, Callable] = {}
        self._fired: Set[int] = set()
        self._spike: Dict[int, float] = {}   # worker -> multiplier
        for i, e in enumerate(plan.events):
            if e.at < start_step:
                # history replay on resume: events before the restart
                # point already happened — a crashed worker stays crashed,
                # but nothing re-fires
                self._fired.add(i)
                if e.kind == "worker_crash":
                    self.crashed.add(e.target)
            if resumed and e.kind == "trainer_kill":
                # a kill fires once per run lifetime, or the resumed
                # trainer would re-kill itself at the same step forever
                self._fired.add(i)

    def bind(self, **callbacks: Callable) -> None:
        """Register action callbacks: ``kill_manager()``,
        ``respawn_manager()``, ``kill_self()``, ``crash_worker(worker,
        step)``.  Unbound actions are recorded as skipped."""
        self._cbs.update(callbacks)

    def record(self, step: int, kind: str, **detail: Any) -> None:
        from repro.obs.events import stamp_record
        obs = stamp_record({}, source="fault", kind=kind)
        self.records.append(FaultRecord(step, kind, detail, obs))

    # -- heartbeat filtering (train-side worker crash) ---------------------
    def heartbeat_workers(self, workers: Sequence[int]) -> List[int]:
        return [w for w in workers if w not in self.crashed]

    # -- straggler spikes ---------------------------------------------------
    def spike_for(self, workers: Sequence[int]) -> Optional[List[float]]:
        """Per-stage multipliers for the current worker list, or None when
        no spike is active."""
        if not self._spike:
            return None
        return [self._spike.get(w, 1.0) for w in workers]

    # -- firing -------------------------------------------------------------
    def on_step(self, step: int, *,
                workers: Sequence[int] = ()) -> List[FaultEvent]:
        """Fire every unfired event scheduled at ``step``; returns them.
        ``workers`` is the live stage→worker map (spike target resolution
        and crash-sanity checks)."""
        fired: List[FaultEvent] = []
        for i, e in enumerate(self.plan.events):
            if e.at != step or i in self._fired:
                continue
            self._fired.add(i)
            fired.append(e)
            if e.kind == "worker_crash":
                if workers and e.target not in workers:
                    self.record(step, "worker_crash_skipped",
                                worker=e.target, reason="not active")
                    continue
                self.crashed.add(e.target)
                self.record(step, "worker_crash", worker=e.target)
                cb = self._cbs.get("crash_worker")
                if cb is not None:
                    cb(e.target, step)
            elif e.kind == "straggler_spike":
                target = e.target
                if target < 0:
                    target = workers[-1] if workers else 0
                self._spike[target] = e.value
                self.record(step, "straggler_spike", worker=target,
                            mult=e.value)
            elif e.kind in ("manager_kill", "manager_respawn",
                            "trainer_kill"):
                name = {"manager_kill": "kill_manager",
                        "manager_respawn": "respawn_manager",
                        "trainer_kill": "kill_self"}[e.kind]
                cb = self._cbs.get(name)
                self.record(step, e.kind, bound=cb is not None)
                if cb is not None:
                    cb()
        return fired

    def report(self) -> List[Dict[str, Any]]:
        # flatten: legacy keys at the top level, unified fields merged in
        out = []
        for r in self.records:
            d = {"step": r.step, "kind": r.kind, "detail": dict(r.detail)}
            d.update(r.obs)
            out.append(d)
        return out


class ChaosFileJobManager(FileJobManager):
    """``FileJobManager`` with seeded RPC chaos on the transport hooks."""

    def __init__(self, root: str, plan: FaultPlan,
                 injector: Optional[ChaosInjector] = None, **kw):
        super().__init__(root, **kw)
        self._plan = plan
        self._chaos_rng = random.Random(plan.seed ^ 0x5EED)
        self._injector = injector

    def _chaos_record(self, kind: str, **detail: Any) -> None:
        if self._injector is not None:
            self._injector.record(-1, kind, **detail)

    def _send(self, req_path: str, obj: dict, attempt: int) -> None:
        if self._plan.rpc_delay_s:
            delay = self._chaos_rng.random() * self._plan.rpc_delay_s
            if delay > 0:
                time.sleep(delay)
        # loss only on the first delivery attempt: retries must converge
        # (the retry/backoff path is what the fault exercises)
        if attempt == 0 and self._chaos_rng.random() < self._plan.rpc_loss:
            self._chaos_record("rpc_loss", seq=obj.get("seq"),
                               op=obj.get("op"))
            return                       # message vanished in the network
        super()._send(req_path, obj, attempt)

    def _await(self, resp_path: str, deadline: float, attempt: int) -> dict:
        out = super()._await(resp_path, deadline, attempt)
        if self._chaos_rng.random() < self._plan.rpc_dup:
            # duplicate delivery AFTER the answer: re-publish the same
            # request; the server's seq dedup must ignore it
            seq = out.get("seq")
            if seq is not None:
                self._chaos_record("rpc_dup", seq=seq, op=out.get("op"))
                req_path = resp_path.replace("resp-", "req-")
                super()._send(req_path,
                              {"op": out.get("op"), "seq": seq}, attempt)
        return out
