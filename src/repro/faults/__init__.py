"""Deterministic chaos engineering for the elastic runtime (DESIGN.md §12).

``FaultSpec`` (on the RunSpec) -> ``resolve_plan`` -> ``FaultPlan`` ->
``ChaosInjector`` firing scheduled faults into a live ``Session``; the
``ChaosFileJobManager`` transport adds seeded RPC loss/dup/delay.
"""
from repro.faults.injector import (ChaosFileJobManager, ChaosInjector,
                                   FaultRecord)
from repro.faults.plan import FaultEvent, FaultPlan, resolve_plan

__all__ = ["ChaosFileJobManager", "ChaosInjector", "FaultRecord",
           "FaultEvent", "FaultPlan", "resolve_plan"]
