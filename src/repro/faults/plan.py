"""Deterministic fault schedules (DESIGN.md §12).

A ``FaultPlan`` is the resolved, concrete form of ``api.specs.FaultSpec``:
a sorted list of ``FaultEvent``s keyed to trainer steps / scheduler ticks,
plus per-RPC fault probabilities for the file transport.  ``auto`` mode
derives a randomized-but-seeded schedule from the run shape, so two chaos
runs with the same ``faults.seed`` inject byte-identical fault sequences —
the property the chaos soak's parity assertions rest on.

Event kinds:

  * ``worker_crash``   — the target worker dies silently: it stops
    heartbeating (train) / its stage's KV shard is lost (serve).
  * ``manager_kill``   — SIGKILL the file job-manager server process.
  * ``manager_respawn``— restart the server on the same directory (its
    journal restores the pool).
  * ``trainer_kill``   — SIGKILL this process at a step (after the safe
    point), to be resumed with ``Session.resume``.  Never auto-derived.
  * ``straggler_spike``— the target worker's measured stage times are
    multiplied by ``value`` from this step on (thermal-throttle model).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional

from repro.api.specs import FaultSpec

KINDS = ("worker_crash", "manager_kill", "manager_respawn", "trainer_kill",
         "straggler_spike")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    at: int                       # trainer step / scheduler tick
    kind: str                     # one of KINDS
    target: int = -1              # worker id (crash / spike)
    value: float = 0.0            # multiplier (spike)

    def __post_init__(self):
        assert self.kind in KINDS, self.kind


@dataclasses.dataclass
class FaultPlan:
    """Resolved schedule + RPC fault knobs."""
    events: List[FaultEvent] = dataclasses.field(default_factory=list)
    rpc_loss: float = 0.0
    rpc_dup: float = 0.0
    rpc_delay_s: float = 0.0
    seed: int = 0

    def __post_init__(self):
        self.events = sorted(self.events, key=lambda e: (e.at, e.kind))

    def at(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.at == step]

    @property
    def any_rpc(self) -> bool:
        return bool(self.rpc_loss or self.rpc_dup or self.rpc_delay_s)

    def to_dict(self) -> Dict:
        return {"seed": self.seed, "rpc_loss": self.rpc_loss,
                "rpc_dup": self.rpc_dup, "rpc_delay_s": self.rpc_delay_s,
                "events": [dataclasses.asdict(e) for e in self.events]}


def resolve_plan(fs: FaultSpec, *, horizon: int, workers: int,
                 file_manager: bool) -> FaultPlan:
    """Build the concrete plan for one run.  Explicitly pinned ``FaultSpec``
    fields always win; ``auto`` fills the unset ones from a seeded RNG so
    `--chaos --faults.auto true` exercises a fresh-but-reproducible
    schedule per seed.  ``horizon`` is the step/tick budget the schedule
    must fit inside; ``workers`` the initial worker-id range."""
    events: List[FaultEvent] = []
    rng = random.Random(fs.seed)
    crash = dict(fs.worker_crash or {})
    kill, respawn = fs.manager_kill, fs.manager_respawn
    loss, dup, delay = fs.rpc_loss, fs.rpc_dup, fs.rpc_delay_s
    spikes = dict(fs.straggler_spike or {})
    if fs.auto:
        if not crash and workers > 1 and horizon >= 8:
            # crash a non-zero worker in the middle third of the run
            at = rng.randrange(max(1, horizon // 3),
                               max(2, 2 * horizon // 3))
            crash = {at: rng.randrange(1, workers)}
        if file_manager and kill is None and horizon >= 8:
            kill = rng.randrange(max(1, horizon // 4),
                                 max(2, horizon // 2))
            if respawn is None:
                respawn = kill + max(2, horizon // 10)
        if file_manager and not (loss or dup or delay):
            loss, dup = 0.3, 0.3
        if not spikes and horizon >= 8:
            spikes = {rng.randrange(2 * horizon // 3, horizon): 2.5}
    for at, w in crash.items():
        events.append(FaultEvent(at=at, kind="worker_crash", target=w))
    if kill is not None:
        events.append(FaultEvent(at=kill, kind="manager_kill"))
    if respawn is not None:
        events.append(FaultEvent(at=respawn, kind="manager_respawn"))
    if fs.kill_at is not None:
        events.append(FaultEvent(at=fs.kill_at, kind="trainer_kill"))
    for at, mult in spikes.items():
        # target -1: the injector resolves it to the last stage's worker
        # at fire time (the stage set may have changed by then)
        events.append(FaultEvent(at=at, kind="straggler_spike",
                                 target=-1, value=float(mult)))
    return FaultPlan(events=events, rpc_loss=loss, rpc_dup=dup,
                     rpc_delay_s=delay, seed=fs.seed)
