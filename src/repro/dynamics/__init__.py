from repro.dynamics.config import DynamicsConfig

__all__ = ["DynamicsConfig"]
