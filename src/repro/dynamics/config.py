"""Static configuration of the dynamism scheme applied during training.

One ``kind`` at a time, mirroring the paper's six example cases (MoE routing
imbalance is intrinsic to moe-family archs and needs no kind).  The fields
here are *static* (hashable, part of the jit signature); the *state* of the
dynamism (masks, frozen flags, schedules) lives in the ``dyn`` pytree that is
an input to train_step — so dynamism steps never recompile.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DynamicsConfig:
    kind: str = "none"   # none | moe | pruning | freezing | sparse_attention
                         # | early_exit | mod
    # gradual pruning (Zhu–Gupta schedule, paper Eq. 3)
    prune_initial_sparsity: float = 0.0
    prune_final_sparsity: float = 0.9
    prune_start_iter: int = 3000
    prune_end_iter: int = 7000
    prune_frequency: int = 1000
    # layer freezing (Egeria-style)
    freeze_check_every: int = 50
    freeze_loss_slope_threshold: float = 0.02
    # dynamic sparse flash attention
    sparse_nbuckets: int = 8
    sparse_block: int = 512
    # early exit (CALM-style confidence)
    ee_threshold: float = 0.98
    ee_min_layer_frac: float = 0.25   # no exits before this depth fraction
    # mixture of depths: routing applies around EVERY block (paper §2.6 —
    # tokens may skip both intermediate and final layers; the router+MoE
    # hybrid of Raposo et al. as used by the paper)
    mod_capacity: float = 0.5         # fraction of tokens processed
    mod_every: int = 1                # MoD routing on every k-th block
    # live expert re-layout (LAER-style): when the controller measures
    # hot/cold skew above the watermark it re-places logical experts over
    # physical kernel groups at the next safe point.  Only meaningful for
    # moe-family archs with kernel_impl="pallas".
    expert_relayout: bool = False
    expert_watermark: float = 2.0     # max(load)/mean(load) trigger
    expert_min_tokens: int = 16       # ignore skew below this routed total

    @property
    def uses_sparse_attention(self) -> bool:
        return self.kind == "sparse_attention"

    @property
    def uses_mod(self) -> bool:
        return self.kind == "mod"

    @property
    def uses_early_exit(self) -> bool:
        return self.kind == "early_exit"

    @property
    def uses_freezing(self) -> bool:
        return self.kind == "freezing"


NONE = DynamicsConfig()
