"""Per-iteration dynamism trajectories — the workload generators behind the
paper's six cases (§2.1–§2.6), used by the simulator to reproduce Figs. 1/3/4
and by the controller tests.  Deterministic (seeded) so experiments are
reproducible.

Each generator returns ``List[LayerDynState]`` for iteration k.  Magnitudes
are anchored to the paper's reported imbalance levels: MoE ≤25% (Mixtral),
MoD ≤18%, freezing up to 40% idleness at 40 layers, early-exit up to 5×
bubble, pruning to 90% sparsity via the Zhu–Gupta schedule.
"""
from __future__ import annotations

import math
from typing import List

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import LayerDynState
from repro.dynamics.config import DynamicsConfig


def zhu_gupta_sparsity(k: int, cfg: DynamicsConfig) -> float:
    """Paper Eq. (3): cubic gradual pruning schedule."""
    t0, t1 = cfg.prune_start_iter, cfg.prune_end_iter
    si, sf = cfg.prune_initial_sparsity, cfg.prune_final_sparsity
    if k < t0:
        return si
    if k >= t1:
        return sf
    frac = (k - t0) / max(1, (t1 - t0))
    return sf + (si - sf) * (1.0 - frac) ** 3


def _layer_rng(L: int, seed: int) -> np.ndarray:
    return np.random.RandomState(seed).rand(L)


def pruning_traj(mc: ModelConfig, cfg: DynamicsConfig, seed: int = 0):
    """Global magnitude pruning is non-uniform across layers: deeper layers
    hold more low-magnitude weights and *adjacent layers prune alike*
    (magnitude distributions vary smoothly with depth), so retained fraction
    varies smoothly per layer around the schedule's global sparsity."""
    L = mc.total_blocks()
    propensity = 0.6 + 0.8 * _smooth_profile(L, seed)    # depth-correlated
    propensity *= np.linspace(0.8, 1.2, L)               # deeper prunes more

    def at(k: int) -> List[LayerDynState]:
        s = zhu_gupta_sparsity((k // max(1, cfg.prune_frequency))
                               * cfg.prune_frequency, cfg)
        r = np.clip(1.0 - s * propensity, 0.05, 1.0)
        # renormalise so the mean matches the global schedule
        r *= max(1e-3, (1.0 - s)) / max(1e-3, r.mean())
        r = np.clip(r, 0.05, 1.0)
        return [LayerDynState(retained=float(x)) for x in r]
    return at


def freezing_traj(mc: ModelConfig, cfg: DynamicsConfig, total_iters: int,
                  seed: int = 0):
    """Egeria-style: a freeze front advances from the first layer; early
    layers converge first.  Front reaches ~70% depth by end of training."""
    L = mc.total_blocks()
    jitter = (_layer_rng(L, seed) * 0.1)

    def at(k: int) -> List[LayerDynState]:
        kk = (k // max(1, cfg.freeze_check_every)) * cfg.freeze_check_every
        front = 0.7 * L * min(1.0, kk / max(1, total_iters * 0.8))
        return [LayerDynState(frozen=(i + jitter[i] * L < front))
                for i in range(L)]
    return at


def sparse_attention_traj(mc: ModelConfig, cfg: DynamicsConfig,
                          seed: int = 0):
    """Hash-based block sparsity fluctuates per layer per iteration; density
    in [0.08, 0.6], depth-correlated (nearby layers attend to similar
    structure).  Paper reports 2.7–4× end-to-end wins at long seq."""
    L = mc.total_blocks()
    base = 0.1 + 0.4 * _smooth_profile(L, seed)

    def at(k: int) -> List[LayerDynState]:
        ph = 2 * math.pi * (k % 997) / 997.0
        dens = np.clip(base + 0.15 * np.sin(
            ph + np.arange(L) * 0.7), 0.08, 0.6)
        return [LayerDynState(attn_density=float(d)) for d in dens]
    return at


def early_exit_traj(mc: ModelConfig, cfg: DynamicsConfig, seed: int = 0):
    """CALM-style: token survival decays after the min-exit depth; later
    layers see a small fraction of tokens (up to ~5× bubble, §2.5)."""
    L = mc.total_blocks()
    i0 = int(cfg.ee_min_layer_frac * L)

    def at(k: int) -> List[LayerDynState]:
        # exit rate strengthens slightly as the model trains
        alpha = 0.08 + 0.12 * min(1.0, k / 5000.0)
        fr = [1.0 if i <= i0 else float(np.exp(-alpha * (i - i0)))
              for i in range(L)]
        return [LayerDynState(token_frac=max(0.05, f)) for f in fr]
    return at


def _smooth_profile(L: int, seed: int) -> np.ndarray:
    """Depth-correlated persistent profile in [0, 1]: adjacent layers route
    similarly (empirically, MoE hotness varies smoothly with depth), so a
    uniform contiguous split groups hot layers together — the imbalance the
    paper measures."""
    r = np.random.RandomState(seed)
    walk = np.cumsum(r.randn(L))
    walk = np.convolve(walk, np.ones(3) / 3, mode="same")
    lo, hi = walk.min(), walk.max()
    return (walk - lo) / max(1e-9, hi - lo)


def moe_traj(mc: ModelConfig, cfg: DynamicsConfig, seed: int = 0):
    """Routing imbalance: hottest expert ≤ ~1.25× mean (Mixtral, §2.1).

    Hot experts are *persistent* (router weights + data distribution change
    slowly) and *depth-correlated* (nearby layers route alike): each layer
    has a slowly-drifting smooth base imbalance plus small per-iteration
    jitter — which is why the paper's profile-at-k, rebalance-for-k+1 loop
    works, and why a uniform contiguous split eats the full 25%."""
    L = mc.total_blocks()
    base = _smooth_profile(L, seed)

    def at(k: int) -> List[LayerDynState]:
        drift = np.sin(2 * math.pi * (k / 3000.0) + np.arange(L) * 0.35)
        r = np.random.RandomState((seed * 7919 + k) % (2 ** 31))
        hot = 1.0 + 0.25 * np.clip(
            0.85 * base + 0.25 * drift + 0.04 * r.randn(L), 0, 1)
        # episodic router collapse in contiguous DEPTH BANDS: adjacent
        # layers (which route alike) concentrate tokens on few experts
        # (hot ≈ capacity bound ~2×) for stretches of iterations — the
        # heavy contiguous tail that makes whole-layer migration pay (§2.1:
        # "imbalance compounds across layers").  Uniform pairs two banded
        # layers (3.6c); DynMo isolates them at a triple's cost (≈3.15c).
        phase = (k // 400 + seed) % max(4, L // 6)
        band = np.arange(L) // 3
        spikes = (band * 2654435761 + phase * 97) % (L * 2) < L // 2
        hot = np.where(spikes, np.maximum(hot, 1.7 + 0.3 * base), hot)
        return [LayerDynState(expert_hot=float(h)) for h in hot]
    return at


def mod_traj(mc: ModelConfig, cfg: DynamicsConfig, seed: int = 0):
    """Mixture-of-Depths: capacity routing on every k-th block; persistent
    depth-correlated router bias + jitter yields ≤18% load swing (§2.6)."""
    L = mc.total_blocks()
    base = _smooth_profile(L, seed + 1)

    def at(k: int) -> List[LayerDynState]:
        drift = np.sin(2 * math.pi * (k / 2500.0) + np.arange(L) * 0.3)
        r = np.random.RandomState((seed * 104729 + k) % (2 ** 31))
        phase = (k // 300 + seed) % max(4, L // 4)
        out = []
        for i in range(L):
            if cfg.mod_every == 1 or i % cfg.mod_every == 1:
                f = cfg.mod_capacity * (1.0 + 0.36 * (
                    0.7 * (base[i] - 0.5) + 0.2 * drift[i]
                    + 0.1 * (r.rand() - 0.5)))
                # router mis-prediction episodes in depth bands: the MLP
                # predictor (paper §2.6a) intermittently under-selects,
                # pushing adjacent MoD layers back toward full compute
                if ((i // 4) * 2654435761 + phase * 89) % (L * 2) < L // 4:
                    f = max(f, 0.95)
            else:
                f = 1.0
            out.append(LayerDynState(token_frac=float(np.clip(f, 0.05, 1.0))))
        return out
    return at


def make_trajectory(kind: str, mc: ModelConfig, cfg: DynamicsConfig,
                    total_iters: int = 10000, seed: int = 0):
    if kind == "pruning":
        return pruning_traj(mc, cfg, seed)
    if kind == "freezing":
        return freezing_traj(mc, cfg, total_iters, seed)
    if kind == "sparse_attention":
        return sparse_attention_traj(mc, cfg, seed)
    if kind == "early_exit":
        return early_exit_traj(mc, cfg, seed)
    if kind == "moe":
        return moe_traj(mc, cfg, seed)
    if kind == "mod":
        return mod_traj(mc, cfg, seed)
    if kind == "none":
        L = mc.total_blocks()
        return lambda k: [LayerDynState() for _ in range(L)]
    raise ValueError(kind)
