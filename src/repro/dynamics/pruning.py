"""Gradual global magnitude pruning (paper §3.2.1, Algorithm 1) — TPU-native.

Adaptation (DESIGN.md §3): element-wise CSR pruning does not accelerate the
MXU, so we prune *feature blocks* of width 128 (the MXU tile) from the FFN
up-projections.  Algorithm 1's local-topk → gather → global-topk → scatter
becomes an exact global top-k over block magnitude scores computed on the
stage-sharded stacked weights — XLA SPMD partitions the reduction, which is
the collective-equivalent of the paper's NCCL gather/scatter (and exact,
whereas Alg. 1's two-level topk is exact too).

The resulting ``ff_mask`` [S, L_max, n_blocks] is the runtime dyn input; the
``pruned_matmul`` Pallas kernel (and the masked XLA fallback) skip dead
blocks.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (BLOCK_DEC, BLOCK_DENSE, BLOCK_ENC,
                                BLOCK_MLSTM, BLOCK_PAD, ModelConfig)
from repro.models.blocks import PRUNE_BLOCK, n_prune_blocks


def block_magnitudes(cfg: ModelConfig, stage_params: Dict[str, jax.Array]
                     ) -> jax.Array:
    """L2 magnitude per prunable feature block: [S, L_max, n_blocks].

    Dense/enc/dec archs: blocks of d_ff columns of (wi, wg) + rows of wo;
    mLSTM: blocks of the up-projection columns."""
    npb = n_prune_blocks(cfg)

    def score(*mats_cols):
        # mats_cols: arrays [S, L, d, F] (column-blocked) or [S, L, F, d]
        tot = None
        for m, axis in mats_cols:
            S, L = m.shape[0], m.shape[1]
            if axis == "col":
                F = m.shape[3]
                v = jnp.sum(jnp.square(m.astype(jnp.float32)).reshape(
                    S, L, m.shape[2], npb, F // npb), axis=(2, 4))
            else:
                F = m.shape[2]
                v = jnp.sum(jnp.square(m.astype(jnp.float32)).reshape(
                    S, L, npb, F // npb, m.shape[3]), axis=(3, 4))
            tot = v if tot is None else tot + v
        return jnp.sqrt(tot)

    if "wi" in stage_params:        # dense
        return score((stage_params["wi"], "col"), (stage_params["wg"], "col"),
                     (stage_params["wof"], "row"))
    if "e_w1" in stage_params and "wi" not in stage_params:
        s = score((stage_params["e_w1"], "col"), (stage_params["e_w2"], "row"))
        if "d_w1" in stage_params:
            s = s + score((stage_params["d_w1"], "col"),
                          (stage_params["d_w2"], "row"))
        return s
    if "x_up" in stage_params:      # mLSTM up-projection
        return score((stage_params["x_up"], "col"))
    if "ewi" in stage_params:       # MoE experts: score summed over experts
        S, L, E, d, F = stage_params["ewi"].shape
        wi = stage_params["ewi"].astype(jnp.float32)
        wg = stage_params["ewg"].astype(jnp.float32)
        v = (jnp.sum(jnp.square(wi).reshape(S, L, E, d, npb, F // npb),
                     axis=(2, 3, 5))
             + jnp.sum(jnp.square(wg).reshape(S, L, E, d, npb, F // npb),
                       axis=(2, 3, 5)))
        return jnp.sqrt(v)
    raise ValueError("no prunable parameters found")


@functools.partial(jax.jit, static_argnames=("cfg", "keep_blocks"))
def global_block_prune(cfg: ModelConfig, stage_params, tags, keep_blocks: int
                       ) -> jax.Array:
    """Exact global top-k over block magnitudes → ff_mask [S, L_max, npb].

    PAD slots are excluded (−inf) and always masked."""
    mag = block_magnitudes(cfg, stage_params)          # [S, L, npb]
    active = (tags != BLOCK_PAD)[..., None]
    mag = jnp.where(active, mag, -jnp.inf)
    flat = mag.reshape(-1)
    k = min(keep_blocks, flat.shape[0])
    thresh = jax.lax.top_k(flat, k)[0][-1]
    mask = (mag >= thresh) & active & jnp.isfinite(mag)
    return mask.astype(jnp.float32)


def target_keep_blocks(cfg: ModelConfig, num_active_layers: int,
                       sparsity: float) -> int:
    npb = n_prune_blocks(cfg)
    total = num_active_layers * npb
    return max(num_active_layers, int(round(total * (1.0 - sparsity))))
