"""Elastic continuous-batching server on ``ElasticEngine`` worlds.

The server owns one ``EngineState`` whose ``cache`` field is the live KV
state; prefill/decode run on the engine's per-stage-count worlds (compiled
once per world, exactly like the trainer's step), and resizes happen at
the *safe point between decode ticks* — no microbatch is in flight, so the
re-split gathers every lane's KV line onto the new world bit-identically.

Scaling is signal-driven through ``cluster.autoscaler.Autoscaler``'s load
path: queue depth / p95-latency pressure grows the pipeline (workers
re-granted by the job manager), sustained low occupancy with an empty
queue shrinks it (workers released through the ``JobManagerClient``
boundary — same RPC the trainer uses, so ``--job-manager file`` puts a
real process on the other side of a serving resize too).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster.autoscaler import Autoscaler
from repro.cluster.rpc import JobManagerClient
from repro.kernels.paged_attention import paged_tile_work
from repro.configs.base import DistConfig, ModelConfig
from repro.dynamics.config import DynamicsConfig
from repro.launch.engine import ElasticEngine
from repro.pipeline.pipeline import PipelineShapes
from repro.serve.requests import Request, RequestQueue
from repro.serve.scheduler import Scheduler


def _merge_lanes(old, new, mask: np.ndarray):
    """Take admitted lanes' KV lines from ``new``; keep the rest.  Leaves
    are [S, L_max, m, B, ...]; ``mask`` is [m, B]."""
    mj = jnp.asarray(mask)

    def merge(o, n):
        mm = mj.reshape((1, 1) + mj.shape + (1,) * (o.ndim - 4))
        return jnp.where(mm, n, o)

    return jax.tree.map(merge, old, new)


def _permute_lanes(cache, src_of_dst: np.ndarray, m: int, B: int):
    """Apply a defrag lane permutation to every cache leaf."""
    perm = jnp.asarray(src_of_dst)

    def p(a):
        flat = a.reshape(a.shape[:2] + (m * B,) + a.shape[4:])
        return jnp.take(flat, perm, axis=2).reshape(a.shape)

    return jax.tree.map(p, cache)


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


class ElasticServer:
    """Continuous-batching inference with live worker elasticity."""

    def __init__(self, cfg: ModelConfig, dcfg: DistConfig,
                 dyncfg: DynamicsConfig, shapes: PipelineShapes, *,
                 data: int = 1, job_manager: Optional[JobManagerClient] = None,
                 scaler: Optional[Autoscaler] = None, min_stages: int = 1,
                 eos_id: Optional[int] = None, defrag_every: int = 0,
                 seed: int = 0, measure_stage_times: bool = False,
                 initial_workers: Optional[Sequence[int]] = None,
                 in_step_timing: bool = False, tracer=None, metrics=None,
                 paged=None, temperature: float = 0.0,
                 micro_variants: bool = True):
        assert shapes.cache_len >= shapes.seq, "cache must hold the prompt"
        # paged: a serve.kv.PagedKVConfig — KV lives in a block pool indexed
        # by per-lane page tables instead of per-lane contiguous lines.
        # temperature > 0 samples per lane (0 = argmax, bit-exact).
        # micro_variants: decode with the per-live-micro-count variant so
        # drained trailing microbatch rows skip their pipeline ticks.
        self.paged = paged
        self.temperature = float(temperature)
        self.micro_variants = micro_variants
        self.seed = seed
        self.engine = ElasticEngine(cfg, dcfg, dyncfg, shapes, data=data,
                                    job_manager=job_manager,
                                    in_step_timing=in_step_timing,
                                    paged=paged, temperature=temperature)
        if initial_workers is not None:
            # multi-tenant start: serve on exactly the workers the cluster
            # scheduler granted (arbitrary global ids, possibly fewer than
            # the spec's max stages) — same bind + sized-init path the
            # checkpoint resume uses
            self.engine.bind_workers([int(w) for w in initial_workers])
            self.state = self.engine.init_state(
                jax.random.PRNGKey(seed), with_opt=False, with_cache=True,
                stages=len(list(initial_workers)))
        else:
            self.state = self.engine.init_state(
                jax.random.PRNGKey(seed), with_opt=False, with_cache=True)
        self.shapes = shapes
        self.scaler = scaler
        self.min_stages = max(1, min_stages)
        self.max_stages = dcfg.num_stages
        self.eos_id = eos_id
        self.defrag_every = defrag_every
        self.measure_stage_times = measure_stage_times
        self.in_step_timing = in_step_timing
        self.tracer = tracer     # obs.trace.Tracer (None = tracing off)
        self.metrics = metrics   # obs.metrics.MetricsRegistry (optional)
        self._sched: Optional[Scheduler] = None
        # paged prefill scratch: a dense stage-sharded cache prefill writes
        # whole lanes into before pack_pages scatters the admitted lanes'
        # prompt pages into the pool; rebuilt per stage count, disposable
        self._scratch = None
        self._scratch_stages = -1

    def close(self) -> None:
        self.engine.close()

    # -- fault path (DESIGN.md §12) ----------------------------------------
    def crash_worker(self, worker: int, tick: int) -> None:
        """A serving worker died mid-flight: its stage's KV shard is gone,
        and every live lane's KV line passed through it.  Requeue all
        in-flight requests (generated tokens carried — re-admission
        rebuilds their KV from the token prefix) and evict the worker; the
        next tick re-admits onto the smaller world.  The degraded run
        completes the exact same request set token-identically, just
        later."""
        if worker not in self.engine.stage_workers:
            return
        if self.state.stages <= 1:
            raise RuntimeError(
                "last serving worker crashed — nothing to rebuild on")
        requeued = (self._sched.requeue_live(tick)
                    if self._sched is not None else [])
        self.state = self.engine.evict(self.state, [worker], step=tick)
        if self.scaler is not None:
            self.scaler.note_resize(tick, self.state.stages)
        print(f"tick {tick:4d} CRASH worker {worker}: requeued "
              f"{len(requeued)} in-flight requests, serving on "
              f"{self.state.stages} stages")

    # -- safe-point resize -------------------------------------------------
    def resize(self, target_stages: int, tick: int, reason: str,
               steal: bool = False) -> bool:
        """Shrink/grow between decode ticks.  Returns True if the world
        changed (grow may be denied by the job manager).  ``steal`` lets an
        urgent grow preempt a lower-priority tenant through the cluster
        scheduler (no-op on single-tenant managers)."""
        st = self.state
        prev = st.stages
        sp = (self.tracer.span("serve.resize", cat="resize", tick=tick,
                               target=target_stages, reason=reason,
                               steal=steal)
              if self.tracer is not None else None)
        if target_stages < prev:
            self.state = self.engine.shrink(st, target_stages, step=tick)
        elif target_stages > prev:
            # an urgent steal goes through jm.steal inside grow(); the RPC
            # transport ships this span's context so the victim's preempt
            # chains onto it cross-process (DESIGN.md §15)
            self.state = self.engine.grow(st, target_stages - prev,
                                          step=tick, steal=steal)
        changed = self.state.stages != prev
        if sp is not None:
            sp.end(stages=self.state.stages, changed=changed)
        if self.metrics is not None and changed:
            rz = self.engine.resizes[-1]
            self.metrics.inc("dynmo_resizes_total", kind=rz.kind,
                             policy="steal" if steal else reason,
                             help="engine resizes by kind")
        if changed:
            rz = self.engine.resizes[-1]
            print(f"tick {tick:4d} {rz.kind.upper()} {rz.from_stages}->"
                  f"{rz.to_stages} stages ({reason}); workers {rz.workers}; "
                  f"pool active={self.engine.jm.num_active}")
            if self.scaler is not None:
                self.scaler.note_resize(tick, self.state.stages)
        return changed

    # -- main loop ----------------------------------------------------------
    def serve(self, requests: List[Request], *, max_ticks: int = 100000,
              resize_at: Optional[Dict[int, int]] = None,
              autoscale: bool = False, injector=None) -> Dict[str, Any]:
        """Drive the request trace to completion.  ``resize_at`` scripts
        {tick: target_stages} safe-point resizes (tests/demos);
        ``autoscale`` lets the attached scaler drive them from load;
        ``injector`` (faults.ChaosInjector) fires scheduled faults at the
        tick safe points — a crashed worker goes through ``crash_worker``."""
        alloc = None
        if self.paged is not None:
            from repro.serve.kv import PageAllocator
            alloc = PageAllocator(
                self.paged.pool_pages, self.paged.page_size,
                max_pages_per_req=(self.shapes.cache_len
                                   // self.paged.page_size),
                prefix_cache=self.paged.prefix_cache)
        sched = Scheduler(self.shapes.num_micro, self.shapes.mb_global,
                          self.shapes.seq, self.shapes.cache_len,
                          RequestQueue(requests), eos_id=self.eos_id,
                          defrag_every=self.defrag_every, allocator=alloc,
                          sample_seed=(self.seed if self.temperature > 0
                                       else None))
        self._sched = sched
        if injector is not None:
            injector.bind(crash_worker=self.crash_worker)
        m, B = self.shapes.num_micro, self.shapes.mb_global
        resizes_before = len(self.engine.resizes)
        tick = 0
        tick_wall: List[float] = []
        tick_tokens: List[int] = []
        token_lat: List[float] = []
        stages_hist: List[int] = []
        depth_hist: List[int] = []
        occ_hist: List[float] = []
        page_occ_hist: List[float] = []
        peak_lanes = 0
        peak_pages = 0
        tiles_live = tiles_total = 0
        moe_drops = []   # device scalars; synced once after the trace drains
        t_run = time.perf_counter()
        while tick < max_ticks and not sched.done:
            t0 = time.perf_counter()
            emitted = 0
            sp_tick = (self.tracer.span("serve.tick", cat="serve",
                                        tick=tick,
                                        stages=self.state.stages)
                       if self.tracer is not None else None)
            adm = sched.plan_admissions(tick)
            if adm is not None and self.tracer is not None:
                self.tracer.instant("serve.admit", cat="serve", tick=tick,
                                    lanes=len(adm.full_len_lanes))
            if adm is not None:
                batch = {"tokens": jnp.asarray(adm.prefill_tokens)}
                if alloc is not None:
                    # prefill into the disposable dense scratch, then
                    # scatter the admitted lanes' prompt pages into the
                    # pool through the admission page table
                    if self._scratch_stages != self.state.stages:
                        self._scratch = self.engine.make_dense_scratch(
                            self.state.stages)
                        self._scratch_stages = self.state.stages
                    ids, self._scratch = self.engine.prefill(
                        self.state, batch, cache=self._scratch)
                    self.engine.pack_pages(self.state, self._scratch,
                                           adm.page_table, adm.pack_mask)
                else:
                    ids, new_cache = self.engine.prefill(self.state, batch)
                    self.state.cache = _merge_lanes(self.state.cache,
                                                    new_cache,
                                                    adm.admit_mask)
                sched.note_prefill(adm, np.asarray(ids), tick)
                emitted += len(adm.full_len_lanes)
                if self.engine.last_moe_drop is not None:
                    moe_drops.append(self.engine.last_moe_drop)
            dec = sched.plan_decode()
            if dec is not None:
                for src, dst in dec.copies:      # CoW forks land on device
                    self.engine.copy_block(self.state, src, dst)
                mlive = ((max(dec.lanes) // B) + 1
                         if self.micro_variants else None)
                ids, _lp = self.engine.decode(self.state,
                                              jnp.asarray(dec.tokens),
                                              jnp.asarray(dec.pos),
                                              page_table=dec.page_table,
                                              seeds=dec.seeds,
                                              live_micros=mlive)
                sched.note_decode(dec, np.asarray(ids), tick)
                emitted += len(dec.lanes)
                peak_lanes = max(peak_lanes, len(dec.lanes))
                if alloc is not None:
                    lv, tt = paged_tile_work(
                        dec.page_table,
                        dec.pos.reshape(-1) + 1, alloc.page_size)
                    tiles_live += lv
                    tiles_total += tt
                if self.engine.last_moe_drop is not None:
                    moe_drops.append(self.engine.last_moe_drop)
            perm = sched.maybe_defrag(tick)
            if perm is not None and alloc is None:
                # dense lines move with their lanes; the paged pool never
                # moves — lanes only carry table rows, rebuilt every tick
                self.state.cache = _permute_lanes(self.state.cache, perm,
                                                  m, B)
            wall = time.perf_counter() - t0
            if sp_tick is not None:
                sp_tick.end(tokens=emitted, queue=sched.queue_depth)
            tick_wall.append(wall)
            tick_tokens.append(emitted)
            token_lat.extend([wall] * emitted)
            stages_hist.append(self.state.stages)
            depth_hist.append(sched.queue_depth)
            occ_hist.append(sched.occupancy)
            if alloc is not None:
                page_occ_hist.append(alloc.occupancy)
                peak_pages = max(peak_pages, alloc.live_pages)
                if self.metrics is not None:
                    self.metrics.set("dynmo_kv_page_occupancy",
                                     alloc.occupancy,
                                     help="KV pool occupancy fraction")
                    self.metrics.set("dynmo_kv_pages_live",
                                     alloc.live_pages,
                                     help="KV pool pages in use")
                    self.metrics.set("dynmo_kv_pages_free", alloc.num_free,
                                     help="KV pool pages free")
            if self.metrics is not None:
                self.metrics.inc("dynmo_serve_ticks_total",
                                 help="decode ticks executed")
                self.metrics.inc("dynmo_serve_tokens_total", emitted,
                                 help="tokens emitted")
                self.metrics.set("dynmo_queue_depth", sched.queue_depth,
                                 help="waiting requests")
                self.metrics.set("dynmo_occupancy", sched.occupancy,
                                 help="lane occupancy fraction")
                self.metrics.observe("dynmo_tick_seconds", wall,
                                     help="serve tick wall seconds")
            # ---- safe point: the tick's flight is fully retired
            if resize_at and tick in resize_at:
                self.resize(resize_at[tick], tick, "scripted")
            elif autoscale and self.scaler is not None:
                # latency signal = p95 per-token over the recent window
                # (what AutoscalerConfig.latency_slo_s is specified
                # against) — never the raw tick wall, which spikes on
                # every fresh-world compile and covers many tokens
                recent = token_lat[-64:]
                d = self.scaler.observe_load(
                    tick, self.state.stages, queue_depth=sched.queue_depth,
                    occupancy=sched.occupancy,
                    latency_s=_pct(recent, 95) if recent else 0.0,
                    page_occupancy=sched.page_occupancy)
                if d.action == "shrink":
                    self.resize(max(self.min_stages,
                                    self.state.stages - d.workers),
                                tick, d.reason)
                elif d.action == "grow":
                    self.resize(min(self.max_stages,
                                    self.state.stages + d.workers),
                                tick, d.reason, steal=d.urgent)
            if injector is not None:
                # scheduled faults fire at the same safe point resizes do:
                # the tick's flight is fully retired, so a crash loses KV
                # state only — never an in-flight microbatch
                injector.on_step(tick, workers=self.engine.stage_workers)
            tick += 1
        wall_s = time.perf_counter() - t_run
        total_tokens = sum(len(r.tokens) for r in sched.completions)
        measured = None
        src = None
        if self.in_step_timing:
            # live per-stage seconds from the in-step stamps accumulated
            # over the trace's prefill/decode calls — no probe execution
            ist = self.engine.in_step_stage_times(self.state)
            if ist is not None:
                measured = list(map(float, ist))
                src = "in_step"
        if measured is None and self.measure_stage_times:
            # per-stage prefill-shaped wall times via the engine's stage
            # probe (off the serving hot loop: one probe after the trace
            # drains, on whatever world the server ended up holding)
            probe_batch = {"tokens": np.zeros(
                (m, B, self.shapes.seq), np.int32)}
            measured = list(map(float, self.engine.measure_stage_times(
                self.state, probe_batch)))
            src = "probe"
        report = {
            "completions": [
                {"rid": r.rid, "kind": r.kind, "arrival": r.arrival,
                 "admitted": r.admitted, "finished": r.finished,
                 "plen": r.plen, "requeues": r.requeues,
                 "tokens": list(map(int, r.tokens))}
                for r in sorted(sched.completions, key=lambda r: r.rid)],
            "ticks": tick,
            "tick_wall_s": tick_wall,
            "tick_tokens": tick_tokens,
            "stages_history": stages_hist,
            "queue_depth_history": depth_hist,
            "occupancy_history": occ_hist,
            "resizes": [dataclasses.asdict(e)
                        for e in self.engine.resizes[resizes_before:]],
            "pool_log": list(self.engine.jm.log)
            if hasattr(self.engine.jm, "log") else [],
            "autoscale_decisions": (
                [dataclasses.asdict(d) for d in self.scaler.decisions]
                if self.scaler is not None else []),
            "requeued_total": sched.requeued_total,
            "total_tokens": total_tokens,
            "wall_s": wall_s,
            "tokens_per_s": total_tokens / max(1e-9, wall_s),
            "latency_p50_s": _pct(token_lat, 50),
            "latency_p95_s": _pct(token_lat, 95),
            "measured_stage_times": measured,
            "stage_time_source": src,
            # MoE capacity-overflow telemetry: mean drop fraction over every
            # prefill/decode call of the trace (None for non-MoE archs)
            "moe_dropped_mean": (float(np.mean([float(d)
                                                for d in moe_drops]))
                                 if moe_drops else None),
            # paged-KV telemetry (zeros/empty in dense mode);
            # peak_live_lanes is tracked either way — it is the
            # concurrency headline the paged-vs-dense bench compares
            "peak_live_lanes": peak_lanes,
            "page_occupancy_history": page_occ_hist,
            "kv_page_size": alloc.page_size if alloc is not None else 0,
            "kv_pages_total": alloc.pool_pages if alloc is not None else 0,
            "peak_live_pages": peak_pages,
            "prefix_hits": alloc.prefix_hits if alloc is not None else 0,
            "cow_forks": alloc.cow_forks if alloc is not None else 0,
            "page_tile_live": tiles_live,
            "page_tile_total": tiles_total,
        }
        if alloc is not None and self.metrics is not None:
            self.metrics.inc("dynmo_prefix_hits_total", alloc.prefix_hits,
                             help="prompt pages shared via prefix cache")
            self.metrics.inc("dynmo_cow_forks_total", alloc.cow_forks,
                             help="copy-on-write page forks")
        return report
