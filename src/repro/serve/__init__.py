"""Elastic serving subsystem (ROADMAP "Elastic serving").

Layering (queue → scheduler → engine worlds):

  requests   — ``Request`` + ``RequestQueue`` admission layer and the bursty
               arrival-trace generator (prompt/gen-length distributions,
               per-request dynamism kind);
  slots      — KV-cache lane manager for the fixed-shape pipeline batch
               (alloc/free/defrag; early-exited sequences vacate lanes
               mid-flight);
  scheduler  — continuous batching: packs prefill admissions and per-lane
               decode into the pipeline's fixed [num_micro, mb_global]
               shapes, each request at its own position;
  server     — ``ElasticServer`` binds the scheduler to ``ElasticEngine``
               execution worlds so the cluster control machinery (job
               manager RPC + autoscaler) can shrink/grow the serving
               pipeline under load, preserving in-flight KV caches.
"""
from repro.serve.requests import Request, RequestQueue, make_trace
from repro.serve.scheduler import Scheduler
from repro.serve.server import ElasticServer
from repro.serve.slots import SlotManager

__all__ = ["Request", "RequestQueue", "make_trace", "Scheduler",
           "SlotManager", "ElasticServer"]
