"""Host-side page allocator for the block-paged KV cache.

The device holds one physical block pool per stage-slot
(``[pool_pages + 1, page_size, n_kv, head_dim]``; the last block is a trash
block that absorbs gated writes).  This allocator owns everything else:

* a **free list** (lowest block first, so allocation order is deterministic
  for a given request schedule),
* **per-request page tables** — ``pages_of[rid][j]`` is the physical block
  backing logical page ``j`` (token positions ``[j*page_size,
  (j+1)*page_size)``) of request ``rid``,
* **refcounted prefix sharing** — a *full* prompt page (one entirely covered
  by prompt tokens) is registered under the hash of the token prefix it
  holds; later requests with the same prefix map the same physical block and
  bump its refcount,
* **copy-on-write** — before a lane writes into a shared block (refcount
  > 1), ``ensure_private`` forks it: a fresh block is allocated, the caller
  copies the bytes on device, and the writer's table is repointed.

Admission reserves a request's whole lifetime footprint up front
(``pages_needed``), so a request never blocks mid-flight on an empty free
list and admission gating cannot deadlock.
"""
from __future__ import annotations

import dataclasses
from bisect import insort
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class PagedKVConfig:
    """Serving-side paged-KV settings (derived from ``RunSpec.serve``)."""

    page_size: int            # tokens per KV block
    pool_pages: int           # physical blocks in the pool (excl. trash)
    prefix_cache: bool = False

    def __post_init__(self) -> None:
        if self.page_size <= 0:
            raise ValueError("page_size must be positive")
        if self.pool_pages <= 0:
            raise ValueError("pool_pages must be positive")


class PageAllocator:
    """Free-list block allocator with refcounted copy-on-write sharing."""

    def __init__(self, pool_pages: int, page_size: int, *,
                 max_pages_per_req: int, prefix_cache: bool = False) -> None:
        if pool_pages <= 0 or page_size <= 0 or max_pages_per_req <= 0:
            raise ValueError("pool_pages/page_size/max_pages must be > 0")
        self.pool_pages = pool_pages
        self.page_size = page_size
        self.max_pages = max_pages_per_req
        self.prefix_cache = prefix_cache
        self._free: List[int] = list(range(pool_pages))   # sorted ascending
        self._refs: List[int] = [0] * pool_pages
        self._pages: Dict[int, List[int]] = {}            # rid -> blocks
        self._prefix: Dict[Tuple[int, ...], int] = {}     # prefix -> block
        self._key_of: Dict[int, Tuple[int, ...]] = {}     # block -> prefix
        self.prefix_hits = 0
        self.cow_forks = 0

    # -- accounting ---------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.pool_pages - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.live_pages / self.pool_pages

    def pages_of(self, rid: int) -> List[int]:
        return self._pages[rid]

    def pages_needed(self, plen: int, gen: int) -> int:
        """Blocks covering every position request ``rid`` will ever write.

        The scheduler writes generated token ``g`` at position
        ``plen - 2 + g`` (the bootstrap re-feed rewrites ``plen - 1``), so
        the max position touched is ``max(plen - 1, plen + gen - 2)``.
        """
        max_pos = max(plen - 1, plen + gen - 2)
        return max_pos // self.page_size + 1

    # -- admission ----------------------------------------------------------
    def _full_prompt_pages(self, plen: int) -> int:
        return plen // self.page_size

    def _prefix_key(self, prompt: Sequence[int], j: int) -> Tuple[int, ...]:
        return tuple(int(t) for t in prompt[:(j + 1) * self.page_size])

    def blocks_required(self, prompt: Sequence[int], gen: int) -> int:
        """Fresh blocks needed to admit, after prefix-cache hits.

        When the bootstrap write position ``plen - 1`` falls inside a shared
        full prompt page (``plen % page_size == 0``), the admitter forks that
        page immediately (``ensure_private``), so one extra block is counted
        here — the fork then runs in the same admission step as this gate and
        can never find the free list empty.
        """
        plen = len(prompt)
        need = self.pages_needed(plen, gen)
        if not self.prefix_cache:
            return need
        hits = {j for j in range(min(need, self._full_prompt_pages(plen)))
                if self._prefix_key(prompt, j) in self._prefix}
        fork = 1 if (plen - 1) // self.page_size in hits else 0
        return need - len(hits) + fork

    def can_admit(self, prompt: Sequence[int], gen: int) -> bool:
        need = self.pages_needed(len(prompt), gen)
        if need > self.max_pages:
            raise ValueError(
                f"request needs {need} pages > table capacity {self.max_pages}")
        return self.blocks_required(prompt, gen) <= len(self._free)

    def admit(self, rid: int, prompt: Sequence[int], gen: int) -> List[int]:
        """Map every page the request will ever touch; returns the table."""
        if rid in self._pages:
            raise ValueError(f"rid {rid} already admitted")
        if not self.can_admit(prompt, gen):
            raise RuntimeError("admit() without can_admit() — pool exhausted")
        plen = len(prompt)
        n = self.pages_needed(plen, gen)
        full = self._full_prompt_pages(plen)
        blocks: List[int] = []
        for j in range(n):
            key = (self._prefix_key(prompt, j)
                   if (self.prefix_cache and j < full) else None)
            hit = self._prefix.get(key) if key is not None else None
            if hit is not None:
                self._refs[hit] += 1
                self.prefix_hits += 1
                blocks.append(hit)
                continue
            blk = self._free.pop(0)
            self._refs[blk] = 1
            if key is not None:
                self._prefix[key] = blk
                self._key_of[blk] = key
            blocks.append(blk)
        self._pages[rid] = blocks
        return blocks

    # -- copy-on-write ------------------------------------------------------
    def ensure_private(self, rid: int, j: int) -> Optional[Tuple[int, int]]:
        """Fork page ``j`` of ``rid`` if shared; returns a (src, dst) block
        copy the caller must apply on device, or None if already private."""
        blocks = self._pages[rid]
        src = blocks[j]
        if self._refs[src] <= 1:
            return None
        if not self._free:
            raise RuntimeError("CoW fork with empty free list — the "
                               "admission gate under-reserved")
        dst = self._free.pop(0)
        self._refs[src] -= 1
        self._refs[dst] = 1
        blocks[j] = dst
        self.cow_forks += 1
        return (src, dst)

    # -- release ------------------------------------------------------------
    def free(self, rid: int) -> None:
        """Drop every mapping of ``rid``; blocks return to the free list as
        their refcounts reach zero (per-block free at EOS)."""
        for blk in self._pages.pop(rid):
            self._refs[blk] -= 1
            if self._refs[blk] == 0:
                key = self._key_of.pop(blk, None)
                if key is not None and self._prefix.get(key) == blk:
                    del self._prefix[key]
                insort(self._free, blk)

    # -- invariants ---------------------------------------------------------
    def check(self) -> None:
        mapped: Dict[int, int] = {}
        for rid, blocks in self._pages.items():
            if len(set(blocks)) != len(blocks):
                raise AssertionError(f"rid {rid} double-maps a block")
            for blk in blocks:
                mapped[blk] = mapped.get(blk, 0) + 1
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate block on the free list")
        for blk, n in mapped.items():
            if blk in free:
                raise AssertionError(f"block {blk} mapped while free")
            if self._refs[blk] != n:
                raise AssertionError(
                    f"block {blk}: refcount {self._refs[blk]} != mappers {n}")
        for blk in range(self.pool_pages):
            if blk not in mapped and blk not in free:
                raise AssertionError(f"block {blk} leaked")
            if blk in free and self._refs[blk] != 0:
                raise AssertionError(f"free block {blk} has refcount")
        if len(free) + len(mapped) != self.pool_pages:
            raise AssertionError("free + live != pool (conservation)")
        for key, blk in self._prefix.items():
            if self._refs[blk] <= 0 or self._key_of.get(blk) != key:
                raise AssertionError("prefix index points at a dead block")
