"""Block-paged KV memory for the serving path.

A physical block pool replaces per-lane contiguous KV lines; requests hold
page tables mapping logical pages to pool blocks, with refcounted
copy-on-write sharing of common prompt prefixes.
"""
from repro.serve.kv.allocator import PageAllocator, PagedKVConfig

__all__ = ["PageAllocator", "PagedKVConfig"]
