"""Continuous-batching scheduler over the fixed-shape pipeline batch.

One scheduler *tick* = (admit new requests → prefill their lanes) then
(one pipelined decode step for every live lane).  The pipeline fns keep
their fixed ``[num_micro, mb_global]`` shapes — the scheduler fills lanes
and masks, it never reshapes:

  * **Admission/prefill.**  Freed lanes are bound to queued requests; one
    prefill call writes the admitted lanes' KV lines (right-padded to the
    cell's ``prompt_len``), and the server merges only those lanes into
    the live cache.  A full-length prompt's first token comes straight
    from the prefill's last-position argmax (exactly the one-shot path);
    a shorter prompt bootstraps by re-feeding its last prompt token at
    position ``plen-1`` — the decode re-writes that position's KV with
    identical values and its output is the first generated token.  The
    pad garbage prefill wrote beyond ``plen`` is invisible: decode masks
    the cache at each lane's OWN length and overwrites the pad positions
    as the lane advances through them.
  * **Decode.**  Every live lane decodes at its own position (the
    pipeline's per-lane ``pos`` path).  Free lanes carry garbage whose
    outputs are ignored and whose stale cache writes are overwritten at
    re-admission.
  * **Early exit.**  A finished (gen budget or EOS) sequence vacates its
    lane the same tick; ``defrag_every`` compacts survivors into the lane
    prefix (``SlotManager.defrag``), moving KV lines without touching
    tokens.

All decisions are functions of the trace and tick number only — a serving
run is bit-deterministic and independent of the execution world's stage
count, which is what the elastic-vs-fixed token-identity guarantee rests
on (see DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.requests import Request, RequestQueue
from repro.serve.slots import SlotManager


@dataclasses.dataclass
class AdmissionPlan:
    """Lanes admitted this tick; ``prefill_tokens`` is the full-shape token
    batch (admitted lanes hold their right-padded prompts, the rest zeros)
    and ``admit_mask`` selects the lanes whose KV lines the merge takes.
    Paged mode adds ``page_table``/``pack_mask`` [m, B, J]: where to scatter
    the admitted lanes' prompt pages out of the prefill scratch."""
    lanes: List[Tuple[int, Request]]
    prefill_tokens: np.ndarray          # [m, B, prompt_len] int32
    admit_mask: np.ndarray              # [m, B] bool
    full_len_lanes: List[int]           # lanes taking token 1 from prefill
    page_table: Optional[np.ndarray] = None   # [m, B, J] int32, -1 unmapped
    pack_mask: Optional[np.ndarray] = None    # [m, B, J] bool


@dataclasses.dataclass
class DecodePlan:
    tokens: np.ndarray                  # [m, B] int32 (free lanes: 0)
    pos: np.ndarray                     # [m, B] int32 per-lane positions
    active: np.ndarray                  # [m, B] bool
    lanes: List[int]                    # flat indices of live lanes
    page_table: Optional[np.ndarray] = None   # [m, B, J] int32, -1 unmapped
    copies: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    seeds: Optional[np.ndarray] = None  # [m, B] int32 per-lane sample seeds


class Scheduler:
    def __init__(self, num_micro: int, mb: int, prompt_len: int,
                 cache_len: int, queue: RequestQueue, *,
                 eos_id: Optional[int] = None, defrag_every: int = 0,
                 allocator=None, sample_seed: Optional[int] = None):
        assert cache_len >= prompt_len
        self.prompt_len = prompt_len
        self.cache_len = cache_len
        self.queue = queue
        self.eos_id = eos_id
        self.defrag_every = defrag_every
        # paged KV: admission gates on free *pages* (the real memory), and
        # lanes only carry page-table rows — freeing a lane releases its
        # pages through the SlotManager shim
        self.allocator = allocator
        if allocator is not None:
            if cache_len % allocator.page_size:
                raise ValueError("cache_len must be a multiple of the KV "
                                 "page size (paged rows == dense rows)")
            self.n_table_pages = cache_len // allocator.page_size
        # per-lane sampling (temperature > 0): seed is a deterministic
        # function of (base seed, rid, position) so requeued lanes replay
        # and resume identically
        self.sample_seed = sample_seed
        self.slots = SlotManager(num_micro, mb, allocator=allocator)
        n = self.slots.n_lanes
        self.cur_tok = np.zeros(n, np.int32)
        self.pos = np.zeros(n, np.int32)
        self.gen_done = np.zeros(n, np.int64)
        self.gen_budget = np.zeros(n, np.int64)
        self.live: Dict[int, Request] = {}
        self.completions: List[Request] = []
        # teacher-forced replay (requeued lanes, DESIGN.md §12): known
        # tokens still to feed through decode to rebuild the KV line; while
        # a lane replays, decode emissions are ignored — the model's
        # predictions are only recorded once it reaches unseen positions
        self.replay: Dict[int, deque] = {}
        self.requeued_total = 0

    # -- signals (autoscaler food) ----------------------------------------
    @property
    def queue_depth(self) -> int:
        return self.queue.depth

    @property
    def occupancy(self) -> float:
        return self.slots.num_active / self.slots.n_lanes

    @property
    def page_occupancy(self) -> Optional[float]:
        """Fraction of pool pages live, or None in dense mode — THE memory
        signal: lane occupancy says how many requests run, page occupancy
        says whether another one fits."""
        return None if self.allocator is None else self.allocator.occupancy

    @property
    def done(self) -> bool:
        return self.queue.exhausted and self.slots.num_active == 0

    # -- tick phases -------------------------------------------------------
    def plan_admissions(self, tick: int) -> Optional[AdmissionPlan]:
        self.queue.poll(tick)
        if not self.queue.pending or self.slots.num_free == 0:
            return None
        m, B = self.slots.num_micro, self.slots.mb
        toks = np.zeros((m, B, self.prompt_len), np.int32)
        mask = np.zeros((m, B), bool)
        lanes: List[Tuple[int, Request]] = []
        full: List[int] = []
        while self.queue.pending and self.slots.num_free > 0:
            if self.allocator is not None:
                # gate on free PAGES, not free lanes: the head request's
                # whole lifetime footprint (after prefix-cache hits) must
                # fit now — no mid-flight allocation, no deadlock.  A head
                # that doesn't fit blocks the queue (FIFO determinism).
                h = self.queue.peek()
                hp = min(h.plen, self.prompt_len)
                hg = min(h.gen, self.cache_len - h.plen + 1)
                if not self.allocator.can_admit(h.prompt[:hp], hg):
                    break
            r = self.queue.pop()
            lane = self.slots.alloc(r.rid)
            # admission owns the runtime fields: serving the same Request
            # objects through a second run must not append onto the first
            # run's token stream.  A requeued request re-enters with its
            # already-generated tokens as ``carried`` — prompt+carried is
            # the effective prompt whose KV this admission rebuilds
            r.admitted = tick
            r.finished = -1
            r.tokens = list(r.carried)
            mi, bi = self.slots.unravel(lane)
            pl = min(r.plen, self.prompt_len)
            toks[mi, bi, :pl] = r.prompt[:pl]
            mask[mi, bi] = True
            self.live[lane] = r
            # the cache line bounds how far the lane can decode: token g
            # is written at plen - 2 + g, which must stay < cache_len
            # (carried tokens were generated under that same budget, so a
            # requeued lane's replay always fits)
            self.gen_budget[lane] = min(r.gen,
                                        self.cache_len - r.plen + 1)
            self.gen_done[lane] = len(r.carried)
            if self.allocator is not None:
                self.allocator.admit(r.rid, r.prompt[:pl],
                                     int(self.gen_budget[lane]))
                # if the bootstrap write position plen-1 landed in a shared
                # full prompt page, fork it now — the gate reserved the
                # block, and pack fills it from this lane's own scratch
                # (so no device copy is needed for an admission-time fork)
                self.allocator.ensure_private(
                    r.rid, (pl - 1) // self.allocator.page_size)
            if r.carried:
                # requeued lane: rebuild the KV line with the SAME ops
                # that originally produced it — the prefill covers the
                # prompt only, and every carried token is teacher-forced
                # through decode (note_decode feeds the known tokens and
                # ignores emissions until the replay drains).  Rebuilding
                # carried positions via prefill would be ULP-different
                # from the decode that first wrote them, and a near-tie
                # argmax downstream can flip — losing token identity.
                if r.plen >= self.prompt_len:
                    # original run took token 1 from the prefill argmax;
                    # resume at its first decode: feed token 1 at plen
                    self.pos[lane] = r.plen
                    self.cur_tok[lane] = int(r.carried[0])
                    rest = r.carried[1:]
                else:
                    # resume at the bootstrap decode (re-feed the last
                    # prompt token at plen-1, exactly like admission did)
                    self.pos[lane] = r.plen - 1
                    self.cur_tok[lane] = int(r.prompt[r.plen - 1])
                    rest = r.carried
                if rest:
                    self.replay[lane] = deque(int(t) for t in rest)
            else:
                # next-decode position is plen-1 either way: full-length
                # lanes take their next token from the prefill argmax
                # (``_record`` advances them), shorter prompts bootstrap by
                # re-feeding their last token there (the decode re-writes
                # that position's KV with identical values and emits the
                # next token)
                self.pos[lane] = r.plen - 1
                if r.plen >= self.prompt_len:
                    full.append(lane)
                else:
                    self.cur_tok[lane] = int(r.prompt[r.plen - 1])
            lanes.append((lane, r))
        if not lanes:
            return None                 # page gate blocked the whole batch
        ptab = pmask = None
        if self.allocator is not None:
            ptab, pmask = self._page_table_for(lanes)
        return AdmissionPlan(lanes, toks, mask, full, ptab, pmask)

    def _page_table_for(self, lanes) -> Tuple[np.ndarray, np.ndarray]:
        """[m, B, J] device page table + prompt-page pack mask for the given
        (lane, request) pairs; other rows stay unmapped (-1)."""
        m, B = self.slots.num_micro, self.slots.mb
        J = self.n_table_pages
        ptab = np.full((m, B, J), -1, np.int32)
        pmask = np.zeros((m, B, J), bool)
        ps = self.allocator.page_size
        for lane, r in lanes:
            mi, bi = self.slots.unravel(lane)
            pgs = self.allocator.pages_of(r.rid)
            ptab[mi, bi, :len(pgs)] = pgs
            pl = min(r.plen, self.prompt_len)
            pmask[mi, bi, :-(-pl // ps)] = True
        return ptab, pmask

    def note_prefill(self, plan: AdmissionPlan, prefill_ids: np.ndarray,
                     tick: int) -> List[Request]:
        """Record first tokens for full-length admissions (may finish
        one-token requests immediately); returns the finished ones."""
        finished: List[Request] = []
        for lane in plan.full_len_lanes:
            mi, bi = self.slots.unravel(lane)
            tok = int(prefill_ids[mi, bi])
            self._record(lane, tok, tick, finished)
        return finished

    def plan_decode(self) -> Optional[DecodePlan]:
        lanes = [ln for ln in self.slots.active_lanes()]
        if not lanes:
            return None
        m, B = self.slots.num_micro, self.slots.mb
        active = (self.slots.owner >= 0).reshape(m, B)
        ptab, copies = None, []
        if self.allocator is not None:
            # copy-on-write: if any lane's write page this tick is still
            # shared, fork it (device block copies the server must apply
            # BEFORE this decode) — then snapshot the remapped table
            ps = self.allocator.page_size
            for lane in lanes:
                wpos = min(int(self.pos[lane]), self.cache_len - 1)
                cp = self.allocator.ensure_private(self.live[lane].rid,
                                                   wpos // ps)
                if cp is not None:
                    copies.append(cp)
            ptab, _ = self._page_table_for(
                [(ln, self.live[ln]) for ln in lanes])
        seeds = None
        if self.sample_seed is not None:
            seeds = np.zeros((m, B), np.int32)
            for lane in lanes:
                mi, bi = self.slots.unravel(lane)
                seeds[mi, bi] = ((self.sample_seed * 1000003
                                  + self.live[lane].rid * 8191
                                  + int(self.pos[lane])) & 0x7FFFFFFF)
        return DecodePlan(self.cur_tok.reshape(m, B).copy(),
                          self.pos.reshape(m, B).copy(), active, lanes,
                          ptab, copies, seeds)

    def note_decode(self, plan: DecodePlan, ids: np.ndarray,
                    tick: int) -> List[Request]:
        finished: List[Request] = []
        for lane in plan.lanes:
            dq = self.replay.get(lane)
            if dq is not None:
                # teacher-forced replay: this decode rebuilt one KV
                # position; advance with the KNOWN next token and drop the
                # model's emission — predictions only count at positions
                # the original run never reached
                self.cur_tok[lane] = dq.popleft()
                self.pos[lane] = self.pos[lane] + 1
                if not dq:
                    del self.replay[lane]
                continue
            mi, bi = self.slots.unravel(lane)
            self._record(lane, int(ids[mi, bi]), tick, finished)
        return finished

    def _record(self, lane: int, tok: int, tick: int,
                finished: List[Request]) -> None:
        r = self.live[lane]
        r.tokens.append(tok)
        self.gen_done[lane] += 1
        self.cur_tok[lane] = tok
        self.pos[lane] = self.pos[lane] + 1
        if (self.gen_done[lane] >= self.gen_budget[lane]
                or (self.eos_id is not None and tok == self.eos_id)):
            r.finished = tick
            self.slots.free(lane)
            del self.live[lane]
            self.completions.append(r)

    def maybe_defrag(self, tick: int) -> Optional[np.ndarray]:
        """On cadence, compact live lanes into the prefix.  Returns the
        ``src_of_dst`` lane permutation the server must apply to the KV
        cache, or None.  Scheduler-side per-lane state moves here."""
        if not self.defrag_every or (tick + 1) % self.defrag_every:
            return None
        perm = self.slots.defrag()
        if perm is None:
            return None
        self.cur_tok = self.cur_tok[perm]
        self.pos = self.pos[perm]
        self.gen_done = self.gen_done[perm]
        self.gen_budget = self.gen_budget[perm]
        self.live = {int(np.nonzero(perm == old)[0][0]): r
                     for old, r in self.live.items()}
        self.replay = {int(np.nonzero(perm == old)[0][0]): dq
                       for old, dq in self.replay.items()}
        return perm

    # -- fault recovery (DESIGN.md §12) ------------------------------------
    def requeue_live(self, tick: int) -> List[Request]:
        """A worker crash lost part of every live lane's KV line (each line
        passes through every stage).  Pull every in-flight request back to
        the FRONT of the queue with its generated-so-far tokens carried;
        re-admission rebuilds the KV from the token prefix and generation
        resumes token-identically.  Returns the requeued requests."""
        requeued = [r for _, r in sorted(self.live.items())]
        for lane in list(self.live):
            self.slots.free(lane)
        self.live.clear()
        self.replay.clear()
        for r in reversed(requeued):
            r.carried = list(r.tokens)
            r.requeues += 1
            self.queue.push_front(r)
        self.requeued_total += len(requeued)
        return requeued
