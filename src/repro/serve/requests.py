"""Request model + admission queue + arrival-trace generation.

Time is *logical* (scheduler ticks), not wall-clock: arrivals keyed to tick
numbers make every serving run deterministic for a given trace/seed, which
is what lets the elastic and fixed-mesh runs be compared token-for-token
(the autoscaler's load signals are functions of queue depth / occupancy,
never of wall time, unless the latency SLO signal is explicitly enabled).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    """One generation request.  ``gen`` counts tokens to produce INCLUDING
    the first post-prompt token; ``kind`` tags the dynamism behavior the
    trace generator modelled for it (e.g. ``early_exit`` requests draw a
    short ``gen`` — the sequence leaves the batch early and vacates its
    KV lane)."""
    rid: int
    arrival: int                    # tick the request enters the queue
    prompt: np.ndarray              # [plen] int32, plen >= 1
    gen: int
    kind: str = "none"
    # runtime bookkeeping (stamped by the scheduler)
    admitted: int = -1
    finished: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    # fault recovery (DESIGN.md §12): tokens generated before the lane's
    # KV was lost to a worker crash.  Re-admission treats prompt+carried as
    # an extended prompt — prefill plus teacher-forced replay rebuilds the
    # KV line, and decoding resumes exactly where the crash cut it off
    carried: List[int] = dataclasses.field(default_factory=list)
    requeues: int = 0

    @property
    def plen(self) -> int:
        return int(len(self.prompt))


class RequestQueue:
    """Arrival stream + pending queue.  ``poll(tick)`` admits arrivals into
    the pending queue; the scheduler pops from it as KV lanes free up."""

    def __init__(self, requests: List[Request]):
        self._arrivals = sorted(requests, key=lambda r: (r.arrival, r.rid))
        self._cursor = 0
        self.pending: Deque[Request] = deque()

    def poll(self, tick: int) -> int:
        """Move requests with arrival <= tick into pending; returns count."""
        n = 0
        while (self._cursor < len(self._arrivals)
               and self._arrivals[self._cursor].arrival <= tick):
            self.pending.append(self._arrivals[self._cursor])
            self._cursor += 1
            n += 1
        return n

    def pop(self) -> Optional[Request]:
        return self.pending.popleft() if self.pending else None

    def peek(self) -> Optional[Request]:
        """Head of the pending queue without popping — page-gated admission
        checks the head's footprint and blocks head-of-line (FIFO stays
        deterministic) rather than admitting around it."""
        return self.pending[0] if self.pending else None

    def push_front(self, r: Request) -> None:
        """Requeue an evicted in-flight request ahead of ordinary arrivals —
        it already waited its turn once."""
        self.pending.appendleft(r)

    @property
    def depth(self) -> int:
        return len(self.pending)

    @property
    def exhausted(self) -> bool:
        return self._cursor >= len(self._arrivals) and not self.pending


def make_trace(n_requests: int, *, prompt_len: int, max_gen: int,
               vocab_size: int, seed: int = 0, min_prompt: int = 1,
               burst_period: int = 0, burst_len: int = 0,
               burst_rate: int = 4, lull_rate: int = 1,
               early_exit_frac: float = 0.0) -> List[Request]:
    """Bursty arrival trace with prompt/gen-length distributions.

    Arrivals follow a square wave: within each ``burst_period``-tick cycle
    the first ``burst_len`` ticks emit ``burst_rate`` requests/tick and the
    rest ``lull_rate`` (``burst_period=0`` → everything arrives at tick 0).
    ``early_exit_frac`` of requests are tagged ``early_exit`` and draw a
    short gen length (upper half of requests exit in the first quarter of
    ``max_gen``) — the serving-side analogue of CALM early exit: their KV
    lanes free early and the batch drains, which is exactly the load shape
    the autoscaler's occupancy watermark consolidates on.
    """
    assert 1 <= min_prompt <= prompt_len
    if burst_period > 0 and (burst_rate * min(burst_len, burst_period)
                             + lull_rate
                             * max(0, burst_period - burst_len)) <= 0:
        raise ValueError(
            f"arrival rate is zero everywhere (burst_rate={burst_rate} x "
            f"burst_len={burst_len}, lull_rate={lull_rate}) — the trace "
            f"would never reach {n_requests} requests")
    rng = np.random.RandomState(seed)
    out: List[Request] = []
    tick = 0
    while len(out) < n_requests:
        if burst_period > 0:
            in_burst = (tick % burst_period) < burst_len
            rate = burst_rate if in_burst else lull_rate
        else:
            rate = n_requests
        for _ in range(rate):
            if len(out) >= n_requests:
                break
            plen = int(rng.randint(min_prompt, prompt_len + 1))
            ee = bool(rng.rand() < early_exit_frac)
            hi = max(2, max_gen // 4) if ee else max_gen
            gen = int(rng.randint(1, hi + 1))
            out.append(Request(
                rid=len(out), arrival=tick,
                prompt=rng.randint(0, vocab_size, plen).astype(np.int32),
                gen=gen, kind="early_exit" if ee else "none"))
        tick += 1
    return out
