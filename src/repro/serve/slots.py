"""KV-cache slot (lane) manager — a thin shim over lane bookkeeping and,
when the paged KV subsystem is on, the block ``PageAllocator``.

The pipeline's serving shapes are fixed — ``[num_micro, mb_global]`` lanes —
but what a lane *owns* depends on the memory model: dense mode binds a lane
to one contiguous KV line; paged mode binds it to a request whose KV lives
in pool blocks managed by ``repro.serve.kv.PageAllocator`` (this manager
then only tracks lane identity, and ``free`` forwards the request's pages
back to the allocator — per-block free at EOS).  Either way continuous
batching is lane bookkeeping: ``alloc`` binds a request to the lowest free
lane (determinism), ``free`` vacates it the tick the request finishes or
early-exits, and ``defrag`` compacts the active lanes into the lane-index
prefix.

Defrag keeps per-microbatch occupancy front-loaded: as early exits punch
holes across microbatches, compaction moves the stragglers together so
trailing microbatch rows drain to fully-empty (a deployment can then skip
them, and the occupancy signal the autoscaler shrinks on reflects real
packing, not fragmentation).  Lanes are independent in the model math, so
moving a request's KV line between lanes never changes its tokens
(property-tested).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class SlotManager:
    """Tracks lane ownership over the flat lane space [0, m*B)."""

    def __init__(self, num_micro: int, mb: int, allocator=None):
        self.num_micro = num_micro
        self.mb = mb
        self.n_lanes = num_micro * mb
        self.owner = np.full(self.n_lanes, -1, np.int64)   # rid or -1
        self._lane_of: Dict[int, int] = {}                 # rid -> lane
        # paged mode: the PageAllocator owning this lane space's KV blocks;
        # freeing a lane releases its request's pages
        self.allocator = allocator

    # -- queries -----------------------------------------------------------
    @property
    def num_active(self) -> int:
        return len(self._lane_of)

    @property
    def num_free(self) -> int:
        return self.n_lanes - self.num_active

    def active_lanes(self) -> List[int]:
        return sorted(self._lane_of.values())

    def lane_of(self, rid: int) -> int:
        return self._lane_of[rid]

    def unravel(self, lane: int):
        return divmod(lane, self.mb)                       # (micro, batch)

    # -- transitions -------------------------------------------------------
    def alloc(self, rid: int) -> int:
        """Bind ``rid`` to the lowest free lane."""
        if rid in self._lane_of:
            raise ValueError(f"request {rid} already holds lane "
                             f"{self._lane_of[rid]}")
        free = np.nonzero(self.owner < 0)[0]
        if free.size == 0:
            raise RuntimeError("no free lane (admission must check "
                               "num_free first)")
        lane = int(free[0])
        self.owner[lane] = rid
        self._lane_of[rid] = lane
        return lane

    def free(self, lane: int) -> int:
        """Vacate a lane; returns the rid that held it.  In paged mode the
        request's pages go back to the allocator in the same transition."""
        if not 0 <= lane < self.n_lanes:
            raise ValueError(f"lane {lane} out of range [0, {self.n_lanes})")
        rid = int(self.owner[lane])
        if rid < 0:
            raise ValueError(f"lane {lane} is already free")
        self.owner[lane] = -1
        del self._lane_of[rid]
        if self.allocator is not None:
            self.allocator.free(rid)
        return rid

    def defrag(self) -> Optional[np.ndarray]:
        """Compact active lanes into the prefix.  Returns ``src_of_dst``
        (a full lane permutation: destination lane i takes the state of
        source lane src_of_dst[i]) or None when already compact.  The
        caller must apply the same permutation to every per-lane array
        (KV cache lines, scheduler lane state)."""
        active = np.nonzero(self.owner >= 0)[0]
        if active.size == 0 or int(active[-1]) == active.size - 1:
            return None                                    # already compact
        free = np.nonzero(self.owner < 0)[0]
        src_of_dst = np.concatenate([active, free]).astype(np.int64)
        self.owner = self.owner[src_of_dst]
        self._lane_of = {int(r): i for i, r in enumerate(self.owner)
                         if r >= 0}
        return src_of_dst

    # -- invariants --------------------------------------------------------
    def check(self) -> None:
        """No lane double-assigned, no request on two lanes, map and owner
        array consistent — raised on violation (used by the tests after
        every transition)."""
        owned = self.owner[self.owner >= 0]
        assert len(set(owned.tolist())) == owned.size, "rid on two lanes"
        assert len(self._lane_of) == owned.size, "map/array out of sync"
        for rid, lane in self._lane_of.items():
            assert self.owner[lane] == rid, (rid, lane, self.owner[lane])
