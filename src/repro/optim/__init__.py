from repro.optim.optimizers import (OptConfig, adafactor_init, adamw_init,
                                    make_optimizer)
from repro.optim.schedule import cosine_schedule, linear_warmup

__all__ = ["OptConfig", "adamw_init", "adafactor_init", "make_optimizer",
           "cosine_schedule", "linear_warmup"]
