"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def linear_warmup(step, warmup: int, base_lr: float):
    return base_lr * jnp.minimum(1.0, (step + 1) / max(1, warmup))


def cosine_schedule(step, total: int, base_lr: float, warmup: int = 100,
                    final_frac: float = 0.1):
    w = jnp.minimum(1.0, (step + 1) / max(1, warmup))
    prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return base_lr * w * cos
