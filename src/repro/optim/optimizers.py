"""Optimizers built from scratch (no optax in this container).

AdamW (f32 moments) and Adafactor (factored second moment — the memory-fit
choice for ≥100B archs, see DESIGN.md); both support:
  * global-norm gradient clipping,
  * per-slot freeze masking (frozen layers get zero updates — pairs with the
    freezable VJP that already skipped their dW compute),
  * gradient compression hooks (runtime/compression.py) for the DP reduce.

State trees mirror the param tree so DynMo migration moves optimizer moments
with their layers (paper §4.1 moves "weights, gradients, optimizer state").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    adafactor_min_dim: int = 128   # factor moments only for big matrices


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), n


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def _adamw_update(cfg: OptConfig, g, m, v, p, t):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    upd = mh / (jnp.sqrt(vh) + cfg.eps)
    if p.ndim >= 2:
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
    return upd, m, v


# ---------------------------------------------------------------------------
# Adafactor (factored v for matrices; falls back to full v for small/1D)
# ---------------------------------------------------------------------------
def adafactor_init(params, min_dim: int = 128):
    def init(p):
        if p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(init, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)
                              or hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32)}


def _adafactor_update(cfg: OptConfig, g, st, p, t):
    decay = 1.0 - (t.astype(jnp.float32)) ** -0.8
    g2 = g * g + 1e-30
    if "vr" in st:
        vr = decay * st["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
        vc = decay * st["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
        denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
        vhat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
        upd = g / jnp.sqrt(vhat + 1e-30)
        new = {"vr": vr, "vc": vc}
    else:
        v = decay * st["v"] + (1 - decay) * g2
        upd = g / jnp.sqrt(v + 1e-30)
        new = {"v": v}
    # update clipping (RMS <= 1) as in the Adafactor paper
    rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)
    upd = upd / jnp.maximum(1.0, rms)
    if p.ndim >= 2:
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
    return upd, new


# ---------------------------------------------------------------------------
# Unified interface
# ---------------------------------------------------------------------------
def make_optimizer(cfg: OptConfig):
    """Returns (init_fn, update_fn).

    update_fn(grads, state, params, lr, frozen=None) -> (params, state, gnorm)
    ``frozen``: optional [S, L_max] mask zeroing updates for stage params.
    """
    def init_fn(params):
        if cfg.name == "adamw":
            return adamw_init(params)
        if cfg.name == "adafactor":
            return adafactor_init(params, cfg.adafactor_min_dim)
        if cfg.name == "sgd":
            return {"count": jnp.zeros((), jnp.int32)}
        raise ValueError(cfg.name)

    def update_fn(grads, state, params, lr, frozen=None):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        t = state["count"] + 1

        def freeze_mask(path_has_stage, upd):
            if frozen is None or not path_has_stage:
                return upd
            keep = (1.0 - frozen).reshape(
                frozen.shape + (1,) * (upd.ndim - 2))
            return upd * keep

        if cfg.name == "adamw":
            flat_p, tdef = jax.tree.flatten(params)
            flat_g = jax.tree.leaves(grads)
            flat_m = jax.tree.leaves(state["m"])
            flat_v = jax.tree.leaves(state["v"])
            outs = [
                _adamw_update(cfg, g, m, v, p, t)
                for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
            upds = [o[0] for o in outs]
            new_state = {"m": jax.tree.unflatten(tdef, [o[1] for o in outs]),
                         "v": jax.tree.unflatten(tdef, [o[2] for o in outs]),
                         "count": t}
        elif cfg.name == "adafactor":
            flat_p, tdef = jax.tree.flatten(params)
            flat_g = jax.tree.leaves(grads)
            fs = state["f"]
            flat_f = jax.tree.leaves(
                fs, is_leaf=lambda x: isinstance(x, dict)
                and ("v" in x or "vr" in x))
            outs = [
                _adafactor_update(cfg, g, f, p, t)
                for g, f, p in zip(flat_g, flat_f, flat_p)]
            upds = [o[0] for o in outs]
            new_f = jax.tree.unflatten(
                jax.tree.structure(
                    fs, is_leaf=lambda x: isinstance(x, dict)
                    and ("v" in x or "vr" in x)),
                [o[1] for o in outs])
            new_state = {"f": new_f, "count": t}
        else:   # sgd
            flat_p, tdef = jax.tree.flatten(params)
            upds = [g for g in jax.tree.leaves(grads)]
            new_state = {"count": t}

        upd_tree = jax.tree.unflatten(tdef, upds)

        def apply_one(path, p, u):
            has_stage = any(getattr(k, "key", None) == "stages"
                            for k in path)
            u = freeze_mask(has_stage, u)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map_with_path(
            apply_one, params, upd_tree)
        return new_params, new_state, gnorm

    return init_fn, update_fn
