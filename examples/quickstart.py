"""Quickstart: train a small GPT with DynMo on a simulated 4-stage pipeline.

Runs on CPU with fake devices:
    PYTHONPATH=src python examples/quickstart.py [--steps 30]

What you see: a tiny GPT training over the pipeline; every 10 steps the DynMo
controller profiles the per-slot stats, and when dynamism (here: gradual
block pruning) skews per-layer cost it migrates layers between stages —
without recompiling the training step.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--dynamism", default="pruning",
                    choices=["none", "pruning", "freezing", "early_exit",
                             "mod", "sparse_attention"])
    ap.add_argument("--balancer", default="diffusion",
                    choices=["diffusion", "partition"])
    args = ap.parse_args()

    from repro.launch.train import run_training
    out = run_training(
        "smollm-360m", steps=args.steps, stages=4, layers=8, d_model=128,
        seq=64, num_micro=4, mb_global=4, dynamism=args.dynamism,
        balancer=args.balancer, rebalance_every=10, log_every=5)
    print(f"\nloss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"({args.steps} steps, {out['wall_s']:.1f}s)")
    print(f"final layers-per-stage: {out['final_lps']}")
    print(f"rebalance events: {len(out['events'])}")
    for ev in out["events"]:
        print(f"  iter {ev.iteration}: imbalance "
              f"{ev.imbalance_before:.3f} -> {ev.imbalance_after:.3f}, "
              f"moved {ev.moved_layers} layers in {ev.decision_s*1e3:.1f}ms")


if __name__ == "__main__":
    main()
