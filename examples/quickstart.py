"""Quickstart: train a small GPT with DynMo on a simulated 4-stage pipeline.

Runs on CPU with fake devices:
    PYTHONPATH=src python examples/quickstart.py [--steps 30]

What you see: a tiny GPT training over the pipeline; every 10 steps the DynMo
controller profiles the per-slot stats, and when dynamism (here: gradual
block pruning) skews per-layer cost it migrates layers between stages —
without recompiling the training step.

Everything is described by one typed ``RunSpec`` (the same object
``--config run.json`` files deserialize to) and executed by a ``Session``;
``session.events`` is the structured telemetry stream.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--dynamism", default="pruning",
                    choices=["none", "pruning", "freezing", "early_exit",
                             "mod", "sparse_attention"])
    ap.add_argument("--balancer", default="diffusion",
                    choices=["diffusion", "partition"])
    args = ap.parse_args()

    from repro.api import (ControllerSpec, DynamicsSpec, ModelSpec,
                           ParallelSpec, RunSpec, Session)
    spec = RunSpec(
        model=ModelSpec(arch="smollm-360m", layers=8, d_model=128),
        parallel=ParallelSpec(stages=4, num_micro=4, mb_global=4, seq=64),
        dynamics=DynamicsSpec(kind=args.dynamism),
        controller=ControllerSpec(balancer=args.balancer,
                                  rebalance_every=10),
        steps=args.steps, log_every=5)

    with Session(spec) as s:
        out = s.train()

    print(f"\nloss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"({args.steps} steps, {out['wall_s']:.1f}s)")
    print(f"final layers-per-stage: {out['final_lps']}")
    rebalances = [ev for ev in s.events if ev.kind == "rebalance"]
    print(f"rebalance events: {len(rebalances)}")
    for ev in rebalances:
        print(f"  iter {ev.data['iteration']}: imbalance "
              f"{ev.data['imbalance_before']:.3f} -> "
              f"{ev.data['imbalance_after']:.3f}, "
              f"moved {ev.data['moved_layers']} layers")


if __name__ == "__main__":
    main()
