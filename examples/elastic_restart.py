"""Fault tolerance + elasticity walkthrough (paper §3.4).

Two modes:

  --mode live (default): the ElasticEngine path — shrink 4→2 stages and
    grow back IN PROCESS, no restart: state is flattened to global layer
    order, re-split, and placed onto a submesh over the surviving devices;
    released workers go back to the WorkerPool and are granted back later.

  --mode restart: the checkpoint-coordinated fallback (§3.4.2) — required
    when the job manager must actually reschedule processes (multi-node
    failures): train, checkpoint, "lose" workers, elastic-restore onto the
    smaller mesh, continue, grow back on recovery.

    PYTHONPATH=src python examples/elastic_restart.py [--mode live|restart]
"""
import argparse
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def _setup():
    from repro.configs import get_config, reduced_config
    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                         vocab_size=512)
    return cfg, 2, 2, 32       # cfg, micro, mbg, seq


def main_live():
    """Engine mode: one process, three worlds, zero restarts."""
    import jax
    import jax.numpy as jnp
    from repro.configs import DistConfig
    from repro.data.loader import DataConfig, make_loader
    from repro.dynamics.config import DynamicsConfig
    from repro.launch.engine import ElasticEngine
    from repro.pipeline.pipeline import PipelineShapes

    cfg, micro, mbg, seq = _setup()
    dcfg = DistConfig(num_stages=4, slot_slack=3, remat="none",
                      param_dtype="float32")
    engine = ElasticEngine(cfg, dcfg, DynamicsConfig(),
                           PipelineShapes(micro, mbg, seq), data=1)
    state = engine.init_state(jax.random.PRNGKey(0))
    loader = make_loader(cfg, DataConfig(micro, mbg, seq))
    it = iter(loader)

    def train_some(n):
        losses = []
        for _ in range(n):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            loss, _, _ = engine.step(state, batch, jnp.float32(3e-4))
            losses.append(float(loss))
        return losses

    print("phase 1: 4-stage training")
    losses1 = train_some(6)
    print(f"  losses: {[f'{l:.3f}' for l in losses1]}")

    print("phase 2: repack decision -> LIVE shrink to 2 stages "
          "(same process, no checkpoint)")
    state = engine.shrink(state, 2, step=6)
    rz = engine.resizes[-1]
    print(f"  released workers {rz.workers} in {rz.seconds*1e3:.0f}ms; "
          f"pool active={engine.pool.num_active}; "
          f"schedule {rz.ticks_before}->{rz.ticks_after} ticks")
    losses2 = train_some(6)
    print(f"  losses: {[f'{l:.3f}' for l in losses2]}")
    assert losses2[0] < losses1[0], "training must continue, not restart"

    print("phase 3: workers recovered -> LIVE grow back to 4 stages")
    state = engine.grow(state, 2, step=12)
    rz = engine.resizes[-1]
    print(f"  granted workers {rz.workers}; "
          f"pool active={engine.pool.num_active}")
    losses3 = train_some(6)
    print(f"  losses: {[f'{l:.3f}' for l in losses3]}")
    print(f"live shrink + regrow completed; loss descended "
          f"{losses1[0]:.3f} -> {losses3[-1]:.3f}; "
          f"pool log: {engine.pool.log}")


def main_restart():
    """Checkpoint-coordinated fallback (§3.4.2) — the restart path."""
    import jax
    import jax.numpy as jnp
    from repro.checkpoint.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.elastic import elastic_restore
    from repro.configs import DistConfig
    from repro.data.loader import DataConfig, make_loader
    from repro.dynamics.config import DynamicsConfig
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_train_step
    from repro.models import model as M
    from repro.pipeline.pipeline import PipelineShapes
    from repro.runtime.fault_tolerance import WorkerPool

    cfg, micro, mbg, seq = _setup()
    ckdir = tempfile.mkdtemp(prefix="dynmo_elastic_")
    pool = WorkerPool(4)

    def train_some(stages, steps, params=None, opt=None, dyn=None,
                   lps=None, start=0):
        dcfg = DistConfig(num_stages=stages, slot_slack=3, remat="none",
                          param_dtype="float32")
        dyncfg = DynamicsConfig()
        mesh = make_host_mesh(data=1, model=stages)
        shapes = PipelineShapes(micro, mbg, seq)
        if params is None:
            params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
            dyn = M.init_dyn(cfg, dcfg, dyncfg)
        else:
            # restored state may live on the previous (smaller/larger)
            # device set — place it onto the new mesh
            from jax.sharding import NamedSharding, PartitionSpec as P
            put = lambda t: jax.tree.map(
                lambda a: jax.device_put(
                    a, NamedSharding(mesh, P(*([None] * a.ndim)))), t)
            params = put(params)
            dyn = put(dyn)
            if opt is not None:
                opt = put(opt)
        assignment = M.make_assignment(cfg, dcfg, lps)
        init_opt, step_fn = make_train_step(cfg, dcfg, dyncfg, mesh, shapes)
        if opt is None:
            opt = init_opt(params)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        loader = make_loader(cfg, DataConfig(micro, mbg, seq),
                             start_step=start)
        losses = []
        with mesh:
            for i, batch in enumerate(loader):
                if i >= steps:
                    break
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, loss, _, _ = jitted(
                    params, opt, assignment, dyn, batch, jnp.float32(3e-4))
                losses.append(float(loss))
        from repro.models.model import assignment_to_boundaries
        return params, opt, dyn, assignment_to_boundaries(assignment), \
            losses, dcfg

    print("phase 1: 4-stage training")
    p, o, d, lps4, losses1, dcfg4 = train_some(4, 6)
    print(f"  losses: {[f'{l:.3f}' for l in losses1]}")
    save_checkpoint(ckdir, 6, p, o, d, lps4)

    print("phase 2: 2 workers fail -> heartbeat detects -> elastic restart "
          "on 2 stages")
    pool.fail(2)
    pool.fail(3)
    print(f"  active workers: {pool.num_active}")
    templates = tuple(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
        for t in (p, o, d))
    p, o, d, index = load_checkpoint(ckdir, templates)
    dcfg2 = DistConfig(num_stages=2, slot_slack=3, remat="none",
                       param_dtype="float32")
    p2, o2, d2, _, lps2 = elastic_restore(
        cfg, dcfg4, dcfg2, p, o, d, index["layers_per_stage"])
    p2, o2, d2, lps2b, losses2, _ = train_some(
        2, 6, params=p2, opt=o2, dyn=d2, lps=lps2, start=6)
    print(f"  losses: {[f'{l:.3f}' for l in losses2]}")
    assert losses2[0] < losses1[0], "training must continue, not restart"

    print("phase 3: workers recovered -> grow back to 4 stages")
    pool.request(2)
    dcfg4b = DistConfig(num_stages=4, slot_slack=3, remat="none",
                        param_dtype="float32")
    p4, o4, d4, _, lps4b = elastic_restore(
        cfg, dcfg2, dcfg4b, p2, o2, d2, lps2b)
    _, _, _, _, losses3, _ = train_some(4, 6, params=p4, opt=o4, dyn=d4,
                                        lps=lps4b, start=12)
    print(f"  losses: {[f'{l:.3f}' for l in losses3]}")
    print("elastic shrink + regrow completed; loss descended "
          f"{losses1[0]:.3f} -> {losses3[-1]:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="live", choices=["live", "restart"])
    args = ap.parse_args()
    (main_live if args.mode == "live" else main_restart)()


if __name__ == "__main__":
    main()
