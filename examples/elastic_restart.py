"""Fault tolerance + elasticity walkthrough (paper §3.4.2):

1. train on 4 pipeline stages with checkpointing;
2. simulate losing half the workers (or re-packing freeing them);
3. elastic-restart the SAME model on 2 stages from the checkpoint;
4. verify the loss trajectory continues seamlessly;
5. grow back to 4 stages when workers return.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    import jax
    import jax.numpy as jnp
    from repro.checkpoint.checkpoint import load_checkpoint, save_checkpoint
    from repro.checkpoint.elastic import elastic_restore
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.data.loader import DataConfig, make_loader
    from repro.dynamics.config import DynamicsConfig
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_train_step
    from repro.models import model as M
    from repro.optim.optimizers import OptConfig, make_optimizer
    from repro.pipeline.pipeline import PipelineShapes
    from repro.runtime.fault_tolerance import HeartbeatMonitor, WorkerPool

    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                         vocab_size=512)
    micro, mbg, seq = 2, 2, 32
    ckdir = tempfile.mkdtemp(prefix="dynmo_elastic_")
    pool = WorkerPool(4)

    def train_some(stages, steps, params=None, opt=None, dyn=None,
                   lps=None, start=0):
        dcfg = DistConfig(num_stages=stages, slot_slack=3, remat="none",
                          param_dtype="float32")
        dyncfg = DynamicsConfig()
        mesh = make_host_mesh(data=1, model=stages)
        shapes = PipelineShapes(micro, mbg, seq)
        if params is None:
            params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
            dyn = M.init_dyn(cfg, dcfg, dyncfg)
        else:
            # restored state may live on the previous (smaller/larger)
            # device set — place it onto the new mesh
            from jax.sharding import NamedSharding, PartitionSpec as P
            put = lambda t: jax.tree.map(
                lambda a: jax.device_put(
                    a, NamedSharding(mesh, P(*([None] * a.ndim)))), t)
            params = put(params)
            dyn = put(dyn)
            if opt is not None:
                opt = put(opt)
        assignment = M.make_assignment(cfg, dcfg, lps)
        init_opt, step_fn = make_train_step(cfg, dcfg, dyncfg, mesh, shapes)
        if opt is None:
            opt = init_opt(params)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
        loader = make_loader(cfg, DataConfig(micro, mbg, seq),
                             start_step=start)
        losses = []
        with mesh:
            for i, batch in enumerate(loader):
                if i >= steps:
                    break
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, loss, _, _ = jitted(
                    params, opt, assignment, dyn, batch, jnp.float32(3e-4))
                losses.append(float(loss))
        from repro.models.model import assignment_to_boundaries
        return params, opt, dyn, assignment_to_boundaries(assignment), \
            losses, dcfg

    print("phase 1: 4-stage training")
    p, o, d, lps4, losses1, dcfg4 = train_some(4, 6)
    print(f"  losses: {[f'{l:.3f}' for l in losses1]}")
    save_checkpoint(ckdir, 6, p, o, d, lps4)

    print("phase 2: 2 workers fail -> heartbeat detects -> elastic restart "
          "on 2 stages")
    pool.fail(2)
    pool.fail(3)
    print(f"  active workers: {pool.num_active}")
    templates = tuple(
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
        for t in (p, o, d))
    p, o, d, index = load_checkpoint(ckdir, templates)
    dcfg2 = DistConfig(num_stages=2, slot_slack=3, remat="none",
                       param_dtype="float32")
    p2, o2, d2, _, lps2 = elastic_restore(
        cfg, dcfg4, dcfg2, p, o, d, index["layers_per_stage"])
    p2, o2, d2, lps2b, losses2, _ = train_some(
        2, 6, params=p2, opt=o2, dyn=d2, lps=lps2, start=6)
    print(f"  losses: {[f'{l:.3f}' for l in losses2]}")
    assert losses2[0] < losses1[0], "training must continue, not restart"

    print("phase 3: workers recovered -> grow back to 4 stages")
    pool.request(2)
    dcfg4b = DistConfig(num_stages=4, slot_slack=3, remat="none",
                        param_dtype="float32")
    p4, o4, d4, _, lps4b = elastic_restore(
        cfg, dcfg2, dcfg4b, p2, o2, d2, lps2b)
    _, _, _, _, losses3, _ = train_some(4, 6, params=p4, opt=o4, dyn=d4,
                                        lps=lps4b, start=12)
    print(f"  losses: {[f'{l:.3f}' for l in losses3]}")
    print("elastic shrink + regrow completed; loss descended "
          f"{losses1[0]:.3f} -> {losses3[-1]:.3f}")


if __name__ == "__main__":
    main()
