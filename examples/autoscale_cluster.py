"""Cluster control-plane demo: async decisions + signal-driven elasticity.

Runs the full §3.3.1/§3.4.2 story on CPU with forced host devices:

  * the DynMo controller decides on a background thread (double-buffered
    stats mailbox — the training thread only publishes snapshots);
  * gradual pruning shrinks the model until the controller's repack
    decision consolidates 4 workers onto 2 *live*;
  * the released workers go back to a job manager running in a SEPARATE
    process (file-backed RPC, `repro.cluster.rpc`);
  * mid-run the released machines "come back" (simulated heartbeat
    recovery) and the autoscaler grows the pipeline to 4 again — no
    `--grow-back` step counting anywhere.

Run:
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python examples/autoscale_cluster.py
"""
import argparse
import os

os.environ.setdefault("REPRO_TRAIN_DEVICES", "4")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--recover-at", type=int, default=18,
                    help="step at which released workers start heartbeating "
                         "again")
    ap.add_argument("--job-manager", default="file",
                    choices=["inproc", "file"])
    args = ap.parse_args()

    from repro.launch.train import run_training
    out = run_training(
        "smollm-360m", steps=args.steps, stages=4, layers=8, d_model=128,
        seq=32, num_micro=4, mb_global=2, dynamism="pruning",
        repack=True, rebalance_every=5, log_every=5,
        async_controller=True, autoscale=True,
        simulate_recover=args.recover_at, job_manager=args.job_manager)

    ctl = out["controller"]
    print(f"\nloss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}; "
          f"controller[{ctl['mode']}] decided={ctl['decided']} "
          f"dropped={ctl['dropped']} stale-rejected={ctl['stale_rejected']}")
    print(f"pool transitions over the {args.job_manager} boundary: "
          f"{out['pool_log']}")
    for rz in out["resizes"]:
        print(f"  {rz['kind']} @step {rz['step']}: {rz['from_stages']}->"
              f"{rz['to_stages']} stages, workers {rz['workers']}, "
              f"schedule {rz['ticks_before']}->{rz['ticks_after']} ticks")
    for d in out["autoscale_decisions"]:
        print(f"  autoscale @step {d['step']}: {d['action']} x{d['workers']}"
              f" ({d['reason']})")
    assert out["final_stages"] == 4, "expected the recovery grow to land"


if __name__ == "__main__":
    main()
