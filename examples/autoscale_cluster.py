"""Cluster control-plane demo: async decisions + signal-driven elasticity.

Runs the full §3.3.1/§3.4.2 story on CPU with forced host devices:

  * the DynMo controller decides on a background thread (double-buffered
    stats mailbox — the training thread only publishes snapshots);
  * gradual pruning shrinks the model until the controller's repack
    decision consolidates 4 workers onto 2 *live*;
  * the released workers go back to a job manager running in a SEPARATE
    process (file-backed RPC, `repro.cluster.rpc`);
  * mid-run the released machines "come back" (simulated heartbeat
    recovery) and the autoscaler grows the pipeline to 4 again — no
    `--grow-back` step counting anywhere.

The whole story is one ``RunSpec`` — serialize it with ``spec.to_json()``
and the identical run is `python -m repro.launch.train --config ...`.

Run:
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python examples/autoscale_cluster.py
"""
import argparse
import os

os.environ.setdefault("REPRO_TRAIN_DEVICES", "4")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--recover-at", type=int, default=18,
                    help="step at which released workers start heartbeating "
                         "again")
    ap.add_argument("--job-manager", default="file",
                    choices=["inproc", "file"])
    args = ap.parse_args()

    from repro.api import (ClusterSpec, ControllerSpec, DynamicsSpec,
                           ModelSpec, ParallelSpec, RepackSpec, RunSpec,
                           Session)
    spec = RunSpec(
        model=ModelSpec(arch="smollm-360m", layers=8, d_model=128),
        parallel=ParallelSpec(stages=4, num_micro=4, mb_global=2, seq=32),
        dynamics=DynamicsSpec(kind="pruning"),
        controller=ControllerSpec(rebalance_every=5,
                                  repack=RepackSpec(enabled=True),
                                  async_decide=True),
        cluster=ClusterSpec(job_manager=args.job_manager, autoscale=True,
                            simulate_recover=args.recover_at),
        steps=args.steps, log_every=5)

    with Session(spec) as s:
        out = s.train()

    ctl = out["controller"]
    print(f"\nloss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}; "
          f"controller[{ctl['mode']}] decided={ctl['decided']} "
          f"dropped={ctl['dropped']} stale-rejected={ctl['stale_rejected']}")
    print(f"pool transitions over the {args.job_manager} boundary: "
          f"{out['pool_log']}")
    for ev in s.events:
        if ev.kind == "resize":
            print(f"  {ev.data['resize_kind']} @step {ev.step}: "
                  f"{ev.data['from_stages']}->{ev.data['to_stages']} "
                  f"stages, workers {ev.data['workers']}, schedule "
                  f"{ev.data['ticks_before']}->{ev.data['ticks_after']} "
                  f"ticks")
        elif ev.kind == "autoscale":
            print(f"  autoscale @step {ev.step}: {ev.data['action']} "
                  f"x{ev.data['workers']} ({ev.data['reason']})")
    assert out["final_stages"] == 4, "expected the recovery grow to land"


if __name__ == "__main__":
    main()
