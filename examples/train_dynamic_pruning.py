"""End-to-end driver: train a ~100M-param GPT for a few hundred steps with
gradual global block pruning (paper §3.2.1, Eq. 3) + DynMo rebalancing +
re-packing + checkpointing.

    PYTHONPATH=src python examples/train_dynamic_pruning.py          # ~30M
    PYTHONPATH=src python examples/train_dynamic_pruning.py --big    # ~100M

The pruning schedule compresses the paper's 3000..7000-iteration window into
this run's horizon; watch ff_mask density fall and the balancer shift layers
toward the stages holding less-pruned layers.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import argparse
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true",
                    help="~100M params (slower on CPU)")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.checkpoint.checkpoint import CheckpointManager
    from repro.data.loader import DataConfig, make_loader
    from repro.dynamics import pruning as prn
    from repro.dynamics.config import DynamicsConfig
    from repro.dynamics.trajectories import zhu_gupta_sparsity
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_train_step
    from repro.models import model as M
    from repro.optim.schedule import cosine_schedule
    from repro.pipeline.pipeline import PipelineShapes

    if args.big:
        cfg = reduced_config(get_config("smollm-360m"), num_layers=12,
                             d_model=512, num_heads=8, num_kv_heads=4,
                             d_ff=2048, vocab_size=4096)
    else:
        cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                             d_model=256, num_heads=8, num_kv_heads=4,
                             d_ff=1024, vocab_size=2048)
    print(f"model: {cfg.param_count()/1e6:.1f}M params, "
          f"{cfg.total_blocks()} blocks")

    stages, micro, mbg, seq = 4, 4, 4, 128
    dcfg = DistConfig(num_stages=stages, slot_slack=2, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind="pruning", prune_start_iter=0,
                            prune_end_iter=args.steps * 10,
                            prune_frequency=1)
    mesh = make_host_mesh(data=1, model=stages)
    shapes = PipelineShapes(micro, mbg, seq)

    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    init_opt, train_step = make_train_step(cfg, dcfg, dyncfg, mesh, shapes)
    opt = init_opt(params)
    step_jit = jax.jit(train_step, donate_argnums=(0, 1))

    # finite per-worker budget (1.1× the unpruned per-stage footprint):
    # consolidation plans fire only once pruning actually shrinks memory
    from repro.core.cost_model import stage_memory_budget
    ctrl = DynMoController(
        cfg, dcfg, dyncfg,
        ControllerConfig(method="diffusion", cost_by="time",
                         rebalance_every=20, repack=True,
                         repack_mem_cap=stage_memory_budget(
                             cfg, micro * mbg * seq, seq,
                             dcfg.bytes_per_param, stages, cap_factor=1.1),
                         repack_target=2))
    ckdir = tempfile.mkdtemp(prefix="dynmo_ck_")
    ckpt = CheckpointManager(ckdir, every=max(20, args.steps // 4))
    loader = make_loader(cfg, DataConfig(micro, mbg, seq))
    tokens_step = micro * mbg * seq

    with mesh:
        for step, batch in enumerate(loader):
            if step >= args.steps:
                break
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            lr = cosine_schedule(jnp.float32(step), args.steps, 3e-4, 20)
            params, opt, loss, stats, gnorm = step_jit(
                params, opt, assignment, dyn, batch, lr)

            # gradual pruning every 20 steps (Zhu–Gupta, Eq. 3)
            if step and step % 20 == 0:
                sp = zhu_gupta_sparsity(step * 10, dyncfg)
                keep = prn.target_keep_blocks(cfg, cfg.total_blocks(), sp)
                dyn = dict(dyn)
                dyn["ff_mask"] = prn.global_block_prune(
                    cfg, params["stages"], assignment["tags"], keep)
                dens = float(jnp.mean(dyn["ff_mask"]))
                print(f"  [prune] target sparsity {sp:.2f}; "
                      f"kept blocks density {dens:.2f}")

            if ctrl.cadence(step + 1):
                # stats sync only on controller cadence (§3.3.1)
                from repro.launch.engine import fold_stats
                stats_np = fold_stats(stats, stages)
                params, opt, dyn, new_assignment, _, ev = ctrl.step(
                    step + 1, stats_np, np.asarray(assignment["tags"]),
                    micro, tokens_step, seq, params, opt, dyn)
                if new_assignment is not None:
                    assignment = new_assignment
                    print(f"  [dynmo] rebalanced -> {ctrl.lps} "
                          f"(imb {ev.imbalance_before:.2f} -> "
                          f"{ev.imbalance_after:.2f}, active workers "
                          f"{ev.active_workers})")
                plan = ctrl.take_resize()
                if plan is not None:
                    print(f"  [repack] plan: consolidate onto "
                          f"{plan.target_stages} workers "
                          f"({plan.policy}); the live path "
                          f"(repro.launch.train --repack) executes this "
                          f"in-process via the ElasticEngine")
                    # advisory-only demo: report once, then keep ordinary
                    # rebalancing running (a standing plan supersedes it)
                    ctrl.ccfg.repack = False
            ckpt.maybe_save(step, params, opt, dyn, ctrl.lps)
            if step % 20 == 0:
                print(f"step {step:4d} loss {float(loss):.4f} "
                      f"gnorm {float(gnorm):.2f}")
    print(f"done. checkpoints at {ckdir}")


if __name__ == "__main__":
    main()
