"""End-to-end driver: train a ~30M..100M-param GPT for a few hundred steps
with gradual global block pruning (paper §3.2.1, Eq. 3) + DynMo rebalancing
+ live re-packing + checkpointing.

    PYTHONPATH=src python examples/train_dynamic_pruning.py          # ~30M
    PYTHONPATH=src python examples/train_dynamic_pruning.py --big    # ~100M

The pruning schedule compresses the paper's 3000..7000-iteration window into
this run's horizon; watch ff_mask density fall, the balancer shift layers
toward the stages holding less-pruned layers, and — once pruning frees
enough memory under the 1.1× per-worker budget — the controller's repack
decision consolidate the pipeline onto 2 workers *live* (Alg. 2).

The run is one ``RunSpec`` executed by a ``Session`` (the identical run is
reachable as `python -m repro.launch.train --config <this spec as json>`).
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import argparse
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true",
                    help="~100M params (slower on CPU)")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    from repro.api import (ControllerSpec, DynamicsSpec, ModelSpec,
                           ParallelSpec, RepackSpec, RunSpec, Session)
    from repro.configs import get_config, reduced_config

    if args.big:
        model = ModelSpec(arch="smollm-360m", layers=12, d_model=512,
                          num_heads=8, num_kv_heads=4, d_ff=2048,
                          vocab_size=4096)
    else:
        model = ModelSpec(arch="smollm-360m", layers=8, d_model=256,
                          num_heads=8, num_kv_heads=4, d_ff=1024,
                          vocab_size=2048)
    cfg = reduced_config(get_config(model.arch), num_layers=model.layers,
                         d_model=model.d_model, num_heads=model.num_heads,
                         num_kv_heads=model.num_kv_heads, d_ff=model.d_ff,
                         vocab_size=model.vocab_size)
    print(f"model: {cfg.param_count()/1e6:.1f}M params, "
          f"{cfg.total_blocks()} blocks")

    ckdir = tempfile.mkdtemp(prefix="dynmo_ck_")
    spec = RunSpec(
        model=model,
        parallel=ParallelSpec(stages=4, num_micro=4, mb_global=4, seq=128),
        dynamics=DynamicsSpec(kind="pruning"),
        # finite per-worker budget (1.1× the unpruned per-stage footprint):
        # consolidation plans fire only once pruning actually shrinks memory
        controller=ControllerSpec(
            rebalance_every=20,
            repack=RepackSpec(enabled=True, mem_cap=1.1, target=2)),
        steps=args.steps, log_every=20, ckpt_dir=ckdir)

    with Session(spec) as s:
        out = s.train()

    print(f"\nloss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f} "
          f"({args.steps} steps, {out['wall_s']:.1f}s)")
    for ev in s.events:
        if ev.kind == "rebalance":
            print(f"  [dynmo] iter {ev.data['iteration']}: imbalance "
                  f"{ev.data['imbalance_before']:.2f} -> "
                  f"{ev.data['imbalance_after']:.2f}, moved "
                  f"{ev.data['moved_layers']} layers")
        elif ev.kind == "resize":
            print(f"  [repack] {ev.data['resize_kind']} @step {ev.step}: "
                  f"{ev.data['from_stages']}->{ev.data['to_stages']} "
                  f"workers, schedule {ev.data['ticks_before']}->"
                  f"{ev.data['ticks_after']} ticks")
    print(f"final stages={out['final_stages']} lps={out['final_lps']}; "
          f"checkpoints at {ckdir}")


if __name__ == "__main__":
    main()
