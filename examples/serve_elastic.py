"""Elastic serving demo: continuous batching + load-driven autoscaling.

A bursty request trace (short early-exit requests around a long-generation
tail) is served twice through the ``Session`` API:

  * **elastic** — the autoscaler watches queue depth and KV-lane occupancy;
    when the burst drains it consolidates the serving pipeline (workers are
    released through the JobManagerClient boundary), and when the second
    burst backs the queue up it grows back;
  * **fixed** — the same spec with ``cluster.autoscale`` off.

The generated tokens are asserted identical request-for-request: a resize
re-splits the in-flight KV caches across the new world bit-exactly, so
elasticity is invisible to the served requests — it only changes how many
workers were held while serving them.

Run:
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python examples/serve_elastic.py
"""
import argparse
import copy
import dataclasses
import os

os.environ.setdefault("REPRO_TRAIN_DEVICES", "4")
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ["REPRO_TRAIN_DEVICES"])

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gen-long", type=int, default=24,
                    help="generation length of the long-tail requests")
    ap.add_argument("--job-manager", default="inproc",
                    choices=["inproc", "file"])
    args = ap.parse_args()

    from repro.api import (ClusterSpec, ModelSpec, ParallelSpec, RunSpec,
                           ServeSpec, Session)
    from repro.serve.requests import Request

    spec = RunSpec(
        model=ModelSpec(arch="smollm-360m", layers=8, d_model=128,
                        d_ff=256),
        parallel=ParallelSpec(stages=4, num_micro=2, mb_global=2),
        cluster=ClusterSpec(job_manager=args.job_manager, autoscale=True),
        serve=ServeSpec(prompt_len=8, gen=args.gen_long, min_stages=2,
                        patience=2, cooldown=3, queue_high=2,
                        occupancy_low=0.6, defrag_every=4))

    # hand-built long-tail trace (Session.serve accepts an explicit trace
    # when the spec's make_trace distribution isn't enough)
    rng = np.random.RandomState(0)
    vocab = spec.model.vocab_size
    prompt = lambda n: rng.randint(0, vocab, n).astype(np.int32)
    trace = [Request(rid=i, arrival=0, prompt=prompt(8), gen=2 + i % 3,
                     kind="early_exit") for i in range(6)]
    trace += [Request(rid=6 + i, arrival=0, prompt=prompt(6),
                      gen=args.gen_long) for i in range(2)]
    t2 = args.gen_long + 14
    trace += [Request(rid=8 + i, arrival=t2 + i // 4, prompt=prompt(8),
                      gen=4) for i in range(6)]

    def serve(autoscale):
        sp = dataclasses.replace(
            spec, cluster=dataclasses.replace(
                spec.cluster,
                # the file job manager only matters when scaling releases
                # workers; keep the fixed baseline in-process
                job_manager=(args.job_manager if autoscale else "inproc"),
                autoscale=autoscale))
        with Session(sp) as s:
            return s.serve(trace=copy.deepcopy(trace))

    print("=== elastic (autoscaled) ===")
    el = serve(True)
    print("=== fixed mesh ===")
    fx = serve(False)

    for a, b in zip(el["completions"], fx["completions"]):
        assert a["tokens"] == b["tokens"], (a["rid"], a["tokens"],
                                            b["tokens"])
    kinds = [(r["kind"], r["from_stages"], r["to_stages"])
             for r in el["resizes"]]
    released = sum(1 for e in el["pool_log"] if e.startswith("release:"))
    held = sum(el["stages_history"]) / len(el["stages_history"])
    print(f"\nserved {len(el['completions'])} requests, "
          f"{el['total_tokens']} tokens each run — identical token streams")
    print(f"elastic resizes: {kinds}; {released} workers released via the "
          f"job manager; mean workers held {held:.1f}/4 "
          f"(fixed run held 4.0/4)")
    print(f"elastic {el['tokens_per_s']:.1f} tok/s  vs  fixed "
          f"{fx['tokens_per_s']:.1f} tok/s  (end-to-end incl. resize "
          f"compiles; see benchmarks/bench_serve.py for the steady-state "
          f"low-load comparison)")


if __name__ == "__main__":
    main()
