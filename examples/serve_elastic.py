"""Elastic serving demo: continuous batching + load-driven autoscaling.

A bursty request trace (short early-exit requests around a long-generation
tail) is served twice through `repro.serve.ElasticServer`:

  * **elastic** — the autoscaler watches queue depth and KV-lane occupancy;
    when the burst drains it consolidates the serving pipeline (workers are
    released through the JobManagerClient boundary), and when the second
    burst backs the queue up it grows back;
  * **fixed** — same trace, no scaling.

The generated tokens are asserted identical request-for-request: a resize
re-splits the in-flight KV caches across the new world bit-exactly, so
elasticity is invisible to the served requests — it only changes how many
workers were held while serving them.

Run:
  REPRO_TRAIN_DEVICES=4 PYTHONPATH=src python examples/serve_elastic.py
"""
import argparse
import copy
import os

os.environ.setdefault("REPRO_TRAIN_DEVICES", "4")
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ["REPRO_TRAIN_DEVICES"])

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gen-long", type=int, default=24,
                    help="generation length of the long-tail requests")
    ap.add_argument("--job-manager", default="inproc",
                    choices=["inproc", "file"])
    args = ap.parse_args()

    from repro.cluster.autoscaler import Autoscaler, AutoscalerConfig
    from repro.cluster.rpc import FileJobManager, spawn_file_manager
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.dynamics.config import DynamicsConfig
    from repro.pipeline.pipeline import PipelineShapes
    from repro.serve import ElasticServer
    from repro.serve.requests import Request

    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                         vocab_size=512)
    dcfg = DistConfig(num_stages=4, slot_slack=2, remat="none",
                      param_dtype="float32")
    shapes = PipelineShapes(num_micro=2, mb_global=2, seq=8,
                            cache_len=8 + args.gen_long)
    rng = np.random.RandomState(0)
    prompt = lambda n: rng.randint(0, cfg.vocab_size, n).astype(np.int32)
    trace = [Request(rid=i, arrival=0, prompt=prompt(8), gen=2 + i % 3,
                     kind="early_exit") for i in range(6)]
    trace += [Request(rid=6 + i, arrival=0, prompt=prompt(6),
                      gen=args.gen_long) for i in range(2)]
    t2 = args.gen_long + 14
    trace += [Request(rid=8 + i, arrival=t2 + i // 4, prompt=prompt(8),
                      gen=4) for i in range(6)]

    def serve(autoscale):
        jm = jm_proc = None
        if autoscale and args.job_manager == "file":
            import tempfile
            jm_dir = tempfile.mkdtemp(prefix="dynmo_serve_demo_")
            jm_proc = spawn_file_manager(jm_dir, 4)
            jm = FileJobManager(jm_dir, timeout_s=60.0)
        scaler = Autoscaler(AutoscalerConfig(
            min_stages=2, max_stages=4, patience=2, cooldown=3,
            queue_high=2, occupancy_low=0.6)) if autoscale else None
        srv = ElasticServer(cfg, dcfg, DynamicsConfig(), shapes,
                            job_manager=jm, scaler=scaler, min_stages=2,
                            seed=0, defrag_every=4)
        try:
            return srv.serve(copy.deepcopy(trace), autoscale=autoscale)
        finally:
            srv.close()
            if jm is not None:
                jm.close()
            if jm_proc is not None:
                try:
                    jm_proc.wait(timeout=10)
                except Exception:
                    jm_proc.kill()

    print("=== elastic (autoscaled) ===")
    el = serve(True)
    print("=== fixed mesh ===")
    fx = serve(False)

    for a, b in zip(el["completions"], fx["completions"]):
        assert a["tokens"] == b["tokens"], (a["rid"], a["tokens"],
                                            b["tokens"])
    kinds = [(r["kind"], r["from_stages"], r["to_stages"])
             for r in el["resizes"]]
    released = sum(1 for e in el["pool_log"] if e.startswith("release:"))
    held = sum(el["stages_history"]) / len(el["stages_history"])
    print(f"\nserved {len(el['completions'])} requests, "
          f"{el['total_tokens']} tokens each run — identical token streams")
    print(f"elastic resizes: {kinds}; {released} workers released via the "
          f"job manager; mean workers held {held:.1f}/4 "
          f"(fixed run held 4.0/4)")
    print(f"elastic {el['tokens_per_s']:.1f} tok/s  vs  fixed "
          f"{fx['tokens_per_s']:.1f} tok/s  (end-to-end incl. resize "
          f"compiles; see benchmarks/bench_serve.py for the steady-state "
          f"low-load comparison)")


if __name__ == "__main__":
    main()
