"""Serve a small model with batched requests through the pipelined decode
path — with CALM-style early exit and DynMo rebalancing between batches.

    PYTHONPATH=src python examples/serve_early_exit.py

Flow: prefill the request batch -> decode tokens with the pipeline ->
between generation rounds the controller rebalances stages using the
token-survival profile (later layers see fewer live tokens, so they are
cheap; DynMo packs more of them per stage).
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    import jax
    import jax.numpy as jnp
    from repro.configs import DistConfig, get_config, reduced_config
    from repro.core.controller import ControllerConfig, DynMoController
    from repro.core.profiler import LayerProfile
    from repro.core.cost_model import LayerDynState, cost_vector
    from repro.dynamics.config import DynamicsConfig
    from repro.launch.mesh import make_host_mesh
    from repro.models import model as M
    from repro.pipeline.pipeline import (PipelineShapes, build_decode_fn,
                                         build_prefill_fn)

    stages, micro, mbg = 4, 2, 4
    seq, gen = 32, 12
    cfg = reduced_config(get_config("smollm-360m"), num_layers=8,
                         d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                         vocab_size=512)
    dcfg = DistConfig(num_stages=stages, slot_slack=3, remat="none",
                      param_dtype="float32")
    dyncfg = DynamicsConfig(kind="early_exit", ee_threshold=0.95,
                            ee_min_layer_frac=0.25)
    mesh = make_host_mesh(data=1, model=stages)
    shapes = PipelineShapes(micro, mbg, seq, cache_len=seq + gen)

    params = M.init_params(jax.random.PRNGKey(0), cfg, dcfg)
    assignment = M.make_assignment(cfg, dcfg)
    dyn = M.init_dyn(cfg, dcfg, dyncfg)
    cache = M.init_cache(cfg, dcfg, micro, mbg, seq + gen)

    prefill = jax.jit(build_prefill_fn(cfg, dcfg, dyncfg, mesh, shapes))
    decode = jax.jit(build_decode_fn(cfg, dcfg, dyncfg, mesh, shapes),
                     donate_argnums=(3,))
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (micro, mbg, seq)),
                         jnp.int32)

    ctrl = DynMoController(cfg, dcfg, dyncfg,
                           ControllerConfig(method="partition",
                                            cost_by="time",
                                            rebalance_every=1))
    with mesh:
        print(f"prefill {micro * mbg} requests of {seq} tokens ...")
        ids, cache, _ = prefill(params, assignment, dyn, cache,
                                {"tokens": tokens})
        outs = [np.asarray(ids)]
        for g in range(1, gen):
            ids, lp, cache, _ = decode(params, assignment, dyn, cache, ids,
                                       jnp.int32(seq + g - 1))
            outs.append(np.asarray(ids))
            if g == gen // 2:
                # serving-time rebalance from the early-exit survival curve
                L = cfg.total_blocks()
                states = [LayerDynState(
                    token_frac=max(0.05, float(np.exp(-0.25 * max(
                        0, i - L * dyncfg.ee_min_layer_frac)))))
                    for i in range(L)]
                t = cost_vector(cfg, mbg * 1, seq + g, states, by="time")
                prof = LayerProfile(t, cost_vector(
                    cfg, mbg, seq + g, states, "param") * dcfg.bytes_per_param,
                    np.zeros(stages), states)
                new_lps, ev = ctrl.decide(prof, g)
                if new_lps:
                    params, _, dyn, assignment, cache = ctrl.apply(
                        new_lps, params, None, dyn, cache)
                    print(f"  [dynmo] mid-serving rebalance -> {ctrl.lps} "
                          f"(imbalance {ev.imbalance_before:.2f} -> "
                          f"{ev.imbalance_after:.2f}) — decode continues on "
                          f"the migrated cache, no recompile")
        gen_tokens = np.stack(outs, axis=-1)    # [micro, mbg, gen]
    print(f"generated {gen_tokens.shape} tokens; sample row:",
          gen_tokens[0, 0].tolist())


if __name__ == "__main__":
    main()
